"""TACZ container: round-trip, ROI decode, corruption detection (ISSUE 2).

The contract:

  * ``tacz.write(compress_amr(...))`` → ``read()`` reproduces every
    level's in-memory reconstruction **bit-identically**;
  * ``read_roi(box)`` equals cropping the full reconstruction with the
    same box, for any box;
  * truncation and payload corruption are *detected* (clean errors, never
    garbage data), via the footer, the index CRC, and per-sub-block CRCs.

Deterministic cases run everywhere; hypothesis sweeps run when the
optional dep is installed (CI always has it).
"""
import os
import zlib

import numpy as np
import pytest

from repro import io as tacz
from repro.core import amr, hybrid
from repro.io import format as fmt
from repro.io import tensor as tacz_tensor


def _roundtrip(tmp_path, res, name="t.tacz"):
    path = os.path.join(str(tmp_path), name)
    tacz.write(path, res)
    return path


def _assert_roi_matches(path, res, box):
    rois = tacz.read_roi(path, box)
    assert len(rois) == len(res.levels)
    for roi, lr in zip(rois, res.levels):
        crop = lr.recon[tuple(slice(lo, hi) for lo, hi in roi.box)]
        np.testing.assert_array_equal(roi.data, crop)


# ------------------------------ round-trip ----------------------------------


@pytest.mark.parametrize("preset", ["run1_z10", "run2_t3"])
def test_full_roundtrip_bit_identical(make_amr_snapshot, preset):
    snap = make_amr_snapshot(preset=preset)
    recons = tacz.read(snap.path)
    for lr, rec in zip(snap.res.levels, recons):
        assert rec.dtype == np.float32
        np.testing.assert_array_equal(lr.recon, rec)


def test_gsp_level_roundtrip(tmp_path):
    ds = amr.synthetic_amr((32, 32, 32), densities=[0.9, 0.1],
                           refine_block=4, seed=7)
    lvl = ds.levels[0]
    lr = hybrid.compress_level(lvl.data, lvl.mask, eb=0.01, unit=4,
                               strategy="gsp")
    assert lr.strategy == "gsp"
    path = os.path.join(str(tmp_path), "gsp.tacz")
    with tacz.TACZWriter(path) as w:
        w.add_compressed(lr)
    [rec] = tacz.read(path)
    np.testing.assert_array_equal(lr.recon, rec)


def test_gsp_nondefault_sz_block_roundtrip(tmp_path):
    """The GSP payload must be encoded with the sz_block the index records
    (regression: reg-branch betas grid was rebuilt with the wrong edge)."""
    rng = np.random.default_rng(4)
    i, j, k = np.mgrid[0:32, 0:32, 0:32].astype(np.float32)
    data = 3.0 * i + 2.0 * j - k + rng.normal(
        scale=0.15, size=(32, 32, 32)).astype(np.float32)
    mask = np.ones(data.shape, dtype=bool)
    lr = hybrid.compress_level(data, mask, eb=0.05, unit=4, strategy="gsp",
                               sz_block=8)
    assert lr.artifacts.results[0].extras.get("branch") == "reg"
    path = os.path.join(str(tmp_path), "gspb.tacz")
    with tacz.TACZWriter(path) as w:
        w.add_compressed(lr)
    [rec] = tacz.read(path)
    np.testing.assert_array_equal(lr.recon, rec)


def test_writer_error_surfaces_and_never_publishes(tmp_path):
    """A background-encoder failure must surface to the producer, make
    close() raise (not report success), and leave no file behind."""
    path = os.path.join(str(tmp_path), "bad.tacz")
    w = tacz.TACZWriter(path, eb=-1.0)  # invalid error bound → worker raises
    with pytest.raises(ValueError):
        # the worker error surfaces through a later add_level or close()
        w.add_level(np.ones((8, 8, 8), np.float32))
        w.add_level(np.ones((8, 8, 8), np.float32))
        w.add_level(np.ones((8, 8, 8), np.float32))
        w.close()
    with pytest.raises(ValueError):
        w.close()
    assert not os.path.exists(path)
    assert not os.path.exists(path + ".tmp")


def test_abandoned_writer_is_reaped_at_gc(tmp_path):
    """A writer dropped without close()/abort() must not leak its encoder
    thread or tmp file, and must never publish the destination path."""
    import gc

    path = os.path.join(str(tmp_path), "leak.tacz")
    w = tacz.TACZWriter(path, eb=1e-2)
    w.add_level(np.ones((8, 8, 8), np.float32))
    thread, tmp = w._thread, w._tmp
    del w
    gc.collect()
    thread.join(timeout=10)
    assert not thread.is_alive()
    assert not os.path.exists(tmp)
    assert not os.path.exists(path)


def test_streaming_write_matches_oneshot(tmp_path, make_amr_snapshot):
    """add_level (background-thread encode) ≡ compress_amr + write."""
    snap = make_amr_snapshot(densities=[0.23, 0.77], seed=3)
    p2 = os.path.join(str(tmp_path), "streamed.tacz")
    with tacz.TACZWriter(p2, eb=snap.eb) as w:
        for lvl in snap.ds.levels:
            w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)
    for a, b in zip(tacz.read(snap.path), tacz.read(p2)):
        np.testing.assert_array_equal(a, b)


def test_write_requires_artifacts(tmp_path):
    ds = amr.synthetic_amr((16, 16, 16), densities=[0.23, 0.77],
                           refine_block=4, seed=0)
    res = hybrid.compress_amr(ds, eb=1e-2, keep_artifacts=False)
    with pytest.raises(ValueError, match="artifacts"):
        tacz.write(os.path.join(str(tmp_path), "x.tacz"), res)
    # merged-4D non-SHE levels are not indexable either
    res = hybrid.compress_amr(ds, eb=1e-2, she=False, strategy="opst")
    with pytest.raises(ValueError, match="she=True"):
        tacz.write(os.path.join(str(tmp_path), "y.tacz"), res)


def test_tmp_file_never_left_behind(tmp_path):
    ds = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                           seed=1)
    res = hybrid.compress_amr(ds, eb=1e-2)
    path = os.path.join(str(tmp_path), "atomic.tacz")
    tacz.write(path, res)
    assert os.path.exists(path)
    assert not os.path.exists(path + ".tmp")


# ------------------------------ ROI decode ----------------------------------


def test_roi_equals_cropped_full_decode(make_amr_snapshot):
    snap = make_amr_snapshot(preset="run1_z10")
    n = snap.ds.finest_shape[0]
    for box in [((0, 8), (0, 8), (0, 8)),
                ((5, 23), (11, 40), (2, 9)),
                ((n - 8, n), (n - 16, n), (0, n)),
                ((0, n), (0, n), (0, n))]:
        _assert_roi_matches(snap.path, snap.res, box)


def test_roi_decodes_only_intersecting_subblocks(tmp_path):
    """A small box must touch far fewer payloads than the file holds."""
    ds = amr.load_preset("run1_z10")
    res = hybrid.compress_amr(ds, eb=1e-3)
    path = _roundtrip(tmp_path, res)
    with tacz.TACZReader(path) as rd:
        total = sum(len(e.subblocks) for e in rd.levels)
        reads = []
        orig = rd._decode_subblock

        def counting(li, sb, shape, limit=None):
            reads.append(sb)
            return orig(li, sb, shape, limit=limit)

        rd._decode_subblock = counting
        rd.read_roi(((0, 8), (0, 8), (0, 8)))
    assert total > 20
    assert len(reads) < total / 3


def test_roi_empty_and_out_of_range_box(tmp_path):
    ds = amr.synthetic_amr((32, 32, 32), densities=[0.23, 0.77],
                           refine_block=4, seed=5)
    res = hybrid.compress_amr(ds, eb=1e-3)
    path = _roundtrip(tmp_path, res)
    rois = tacz.read_roi(path, ((40, 50), (0, 8), (0, 8)))  # beyond extent
    for roi in rois:
        assert roi.data.size == 0


# ----------------------- format v2 + write memoization ----------------------


def test_v2_payload_pass_shrinks_and_roundtrips(make_amr_snapshot):
    """v2's lossless byte pass over the Huffman payload sections must be
    recorded per level + per sub-block and decode bit-identically
    (including ROI reads through the prefix-stop path)."""
    raw = make_amr_snapshot(preset="run1_z10", codec="none", name="raw")
    packed = make_amr_snapshot(preset="run1_z10", codec="zlib",
                               name="packed")   # deterministic codec
    res = packed.res
    rd = tacz.TACZReader(packed.path)
    assert rd.version == fmt.TACZ_VERSION == 2
    assert all(e.payload_compressor == fmt.COMPRESSOR_ZLIB
               for e in rd.levels)
    used = [sb.compressor for e in rd.levels for sb in e.subblocks]
    assert fmt.COMPRESSOR_ZLIB in used              # some payloads shrank
    for lr, rec in zip(res.levels, rd.read()):
        np.testing.assert_array_equal(lr.recon, rec)
    _assert_roi_matches(packed.path, res, ((5, 23), (11, 40), (2, 9)))
    # the raw file records COMPRESSOR_NONE everywhere and decodes the same
    rd_raw = tacz.TACZReader(raw.path)
    assert all(sb.compressor == fmt.COMPRESSOR_NONE
               for e in rd_raw.levels for sb in e.subblocks)
    for a, b in zip(rd_raw.read(), rd.read()):
        np.testing.assert_array_equal(a, b)


def test_v1_file_still_readable(tmp_path):
    """A v1-framed container (old index head, raw payloads) must parse and
    decode bit-identically under the v2 reader."""
    from repro.io.writer import build_container, pack_level

    ds = amr.synthetic_amr((32, 32, 32), densities=[0.23, 0.77],
                           refine_block=4, seed=5)
    res = hybrid.compress_amr(ds, eb=1e-3)
    packed = [pack_level(lr, payload_codec="none") for lr in res.levels]
    blob = build_container(packed, version=1)
    with tacz.TACZReader(blob) as rd:
        assert rd.version == 1
        for lr, rec in zip(res.levels, rd.read()):
            np.testing.assert_array_equal(lr.recon, rec)


def test_brick_payload_codec_roundtrip():
    """she.encode_brick_payloads ↔ she.decode_brick_payloads under one
    shared codebook, degenerate streams included."""
    from repro.core import huffman, she

    rng = np.random.default_rng(0)
    streams = [rng.integers(-40, 40, size=n).astype(np.int64)
               for n in (1, 17, 256)] + [np.zeros(9, dtype=np.int64)]
    cb = huffman.build_codebook(np.concatenate(streams))
    payloads = she.encode_brick_payloads(cb, streams)
    decoded = she.decode_brick_payloads(
        cb, [(buf, nbits, s.size)
             for (buf, nbits), s in zip(payloads, streams)])
    for got, want in zip(decoded, streams):
        np.testing.assert_array_equal(got, want)


def test_unknown_payload_codec_rejected(tmp_path):
    with pytest.raises(ValueError, match="codec"):
        tacz.TACZWriter(os.path.join(str(tmp_path), "x.tacz"),
                        payload_codec="lz4")


def test_pack_level_reuses_compress_time_entropy(tmp_path):
    """GSP/global levels must not re-Huffman-encode at write time: the
    compress-time entropy stage's codebook+payload are memoized on
    ``extras['entropy']`` and reused by ``pack_level`` (ROADMAP item)."""
    from repro.core import huffman
    from repro.io import writer as tacz_writer

    ds = amr.synthetic_amr((32, 32, 32), densities=[0.9], refine_block=4,
                           seed=7)
    lvl = ds.levels[0]
    lr = hybrid.compress_level(lvl.data, lvl.mask, eb=0.01, unit=4,
                               strategy="gsp")
    r0 = lr.artifacts.results[0]
    ent = r0.extras.get("entropy")
    assert ent is not None and ent.get("codebook") is not None

    # the memoized pack path never touches the encoder or codebook builder
    def boom(*a, **kw):   # pragma: no cover - failure path
        raise AssertionError("entropy stage re-ran on memoized pack path")

    orig_enc, orig_build = huffman.encode, huffman.build_codebook
    huffman.encode = huffman.build_codebook = boom
    try:
        blob_memo, e_memo = tacz_writer.pack_level(lr, payload_codec="none")
    finally:
        huffman.encode, huffman.build_codebook = orig_enc, orig_build

    # ... and serializes byte-identically to the rebuilt (no-memo) path
    r0.extras = {k: v for k, v in r0.extras.items() if k != "entropy"}
    blob_rebuilt, e_rebuilt = tacz_writer.pack_level(lr,
                                                     payload_codec="none")
    assert blob_memo == blob_rebuilt
    assert e_memo.subblocks[0].crc == e_rebuilt.subblocks[0].crc


# --------------------------- corruption detection ---------------------------


def test_truncated_file_detected(tmp_path):
    ds = amr.synthetic_amr((16, 16, 16), densities=[0.23, 0.77],
                           refine_block=4, seed=2)
    res = hybrid.compress_amr(ds, eb=1e-2)
    path = _roundtrip(tmp_path, res)
    blob = open(path, "rb").read()
    for cut in (len(blob) - 1, len(blob) // 2, 10):
        with pytest.raises(ValueError):
            tacz.TACZReader(blob[:cut])


def test_corrupted_payload_detected_by_crc(tmp_path):
    ds = amr.synthetic_amr((16, 16, 16), densities=[0.23, 0.77],
                           refine_block=4, seed=2)
    res = hybrid.compress_amr(ds, eb=1e-2)
    path = _roundtrip(tmp_path, res)
    blob = bytearray(open(path, "rb").read())
    rd = tacz.TACZReader(bytes(blob))
    assert rd.verify()
    sb = rd.levels[0].subblocks[0]
    blob[sb.payload_off + sb.payload_len - 1] ^= 0xFF
    corrupt = tacz.TACZReader(bytes(blob))
    with pytest.raises(IOError, match="CRC"):
        corrupt.verify()
    with pytest.raises(IOError, match="CRC"):
        corrupt.read_level(0)


def test_corrupted_codebook_and_mask_detected(tmp_path):
    """Section CRCs: a bit flip in a codebook or mask section must fail
    verify() and reads loudly instead of decoding garbage."""
    ds = amr.synthetic_amr((16, 16, 16), densities=[0.23, 0.77],
                           refine_block=4, seed=2)
    res = hybrid.compress_amr(ds, eb=1e-2)
    path = _roundtrip(tmp_path, res)
    good = open(path, "rb").read()
    e = tacz.TACZReader(good).levels[0]
    for off, ln, what in [(e.codebook_off, e.codebook_len, "codebook"),
                          (e.mask_off, e.mask_len, "mask")]:
        assert ln > 0
        blob = bytearray(good)
        blob[off + ln // 2] ^= 0xFF
        corrupt = tacz.TACZReader(bytes(blob))
        with pytest.raises(IOError, match=what):
            corrupt.verify()
        with pytest.raises(IOError, match=what):
            corrupt.read_level(0)


def test_corrupted_index_detected(tmp_path):
    ds = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                           seed=2)
    res = hybrid.compress_amr(ds, eb=1e-2)
    path = _roundtrip(tmp_path, res)
    blob = bytearray(open(path, "rb").read())
    idx_off, _, _ = fmt.parse_footer(bytes(blob))
    blob[idx_off + 5] ^= 0xFF
    with pytest.raises(ValueError, match="index CRC"):
        tacz.TACZReader(bytes(blob))


def test_not_a_tacz_file():
    with pytest.raises(ValueError):
        tacz.TACZReader(b"definitely not a container")
    with pytest.raises(ValueError, match="magic"):
        tacz.TACZReader(fmt.pack_header() + b"\x00" * 64)


# ------------------------------ tensor blobs --------------------------------


@pytest.mark.parametrize("shape", [(128,), (64, 48), (8, 8, 8), (4, 4, 4, 6)])
def test_tensor_blob_roundtrip(shape):
    rng = np.random.default_rng(hash(shape) % 2 ** 31)
    a = (rng.standard_normal(shape) * 0.05).astype(np.float32)
    eb = 1e-4
    blob = tacz_tensor.encode_tensor(a, eb)
    assert blob[:4] == tacz.TACZ_MAGIC
    rec = tacz_tensor.decode_tensor(blob)
    assert rec.shape == a.shape and rec.dtype == np.float32
    assert np.abs(a - rec).max() <= eb + np.abs(a).max() * 2.0 ** -22
    assert len(blob) < a.nbytes  # actually compresses smooth-ish data


def test_tensor_blob_wide_codes_use_int32():
    a = (np.random.default_rng(0).standard_normal((64, 64)) * 1e4
         ).astype(np.float32)
    blob = tacz_tensor.encode_tensor(a, 1e-4)  # |codes| >> 2^15
    with tacz.TACZReader(blob) as rd:
        assert rd.levels[0].subblocks[0].codec == fmt.CODEC_RAW_I32
    rec = tacz_tensor.decode_tensor(blob)
    assert np.abs(a - rec).max() <= 1e-4 + np.abs(a).max() * 2.0 ** -22


# --------------------------- hypothesis sweeps ------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("tacz", max_examples=10, deadline=None)
    settings.load_profile("tacz")
except ImportError:        # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 1000),
           eb=st.floats(1e-3, 0.5),
           fine=st.floats(0.05, 0.95),
           lo=st.tuples(st.integers(0, 30), st.integers(0, 30),
                        st.integers(0, 30)),
           ext=st.tuples(st.integers(1, 32), st.integers(1, 32),
                         st.integers(1, 32)))
    def test_property_roundtrip_and_roi(tmp_path_factory, seed, eb, fine,
                                        lo, ext):
        ds = amr.synthetic_amr((32, 32, 32),
                               densities=[fine, 1.0 - fine],
                               refine_block=4, seed=seed)
        res = hybrid.compress_amr(ds, eb=eb)
        path = os.path.join(str(tmp_path_factory.mktemp("tacz")), "p.tacz")
        tacz.write(path, res)
        for lr, rec in zip(res.levels, tacz.read(path)):
            np.testing.assert_array_equal(lr.recon, rec)
        box = tuple((int(l), int(l + e)) for l, e in zip(lo, ext))
        _assert_roi_matches(path, res, box)
