"""Partition strategies: tiling invariants (DESIGN.md §8.2), DP
correctness, GSP pad/unpad roundtrip — property-based on random occupancy."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.akdtree import akdtree_partition
from repro.core.blocks import make_block_grid, subblocks_tile_exactly
from repro.core.gsp import gsp_pad, gsp_unpad
from repro.core.nast import nast_meta_bits, nast_pack, nast_unpack
from repro.core.opst import compute_bs, opst_partition

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _random_grid(seed, bshape=(6, 6, 6), unit=4, density=0.4):
    rng = np.random.default_rng(seed)
    occ = rng.random(bshape) < density
    data = np.zeros(tuple(b * unit for b in bshape), np.float32)
    mask = np.repeat(np.repeat(np.repeat(occ, unit, 0), unit, 1), unit, 2)
    data[mask] = rng.standard_normal(int(mask.sum())).astype(np.float32) + 5.0
    return make_block_grid(data, mask, unit=unit)


@given(seed=st.integers(0, 5000), density=st.floats(0.05, 0.95))
def test_opst_tiles_exactly(seed, density):
    grid = _random_grid(seed, density=density)
    sbs = opst_partition(grid)
    assert subblocks_tile_exactly(grid, sbs)


@given(seed=st.integers(0, 5000), density=st.floats(0.05, 0.95))
def test_akdtree_tiles_exactly(seed, density):
    grid = _random_grid(seed, density=density)
    sbs = akdtree_partition(grid)
    assert subblocks_tile_exactly(grid, sbs)


@given(seed=st.integers(0, 5000))
def test_akdtree_elongated_grids(seed):
    grid = _random_grid(seed, bshape=(3, 12, 5), density=0.5)
    sbs = akdtree_partition(grid)
    assert subblocks_tile_exactly(grid, sbs)


@given(seed=st.integers(0, 5000))
def test_bs_dp_is_maximal_cube(seed):
    """BS(x,y,z) must equal the true max cube edge ending at (x,y,z)."""
    rng = np.random.default_rng(seed)
    occ = rng.random((5, 5, 5)) < 0.6
    bs = compute_bs(occ)
    for x in range(5):
        for y in range(5):
            for z in range(5):
                best = 0
                for s in range(1, min(x, y, z) + 2):
                    if occ[x - s + 1:x + 1, y - s + 1:y + 1,
                           z - s + 1:z + 1].all():
                        best = s
                assert bs[x, y, z] == best, (x, y, z)


def test_opst_extracts_large_cubes_first():
    occ = np.zeros((6, 6, 6), bool)
    occ[:4, :4, :4] = True       # one 4³ cube
    occ[5, 5, 5] = True          # plus an isolated block
    data = np.zeros((24, 24, 24), np.float32)
    mask = np.repeat(np.repeat(np.repeat(occ, 4, 0), 4, 1), 4, 2)
    data[mask] = 1.0
    grid = make_block_grid(data, mask, unit=4)
    sbs = opst_partition(grid)
    sizes = sorted((sb.bsize for sb in sbs), reverse=True)
    assert sizes[0] == (4, 4, 4)
    assert subblocks_tile_exactly(grid, sbs)


def test_akdtree_leaves_are_full():
    grid = _random_grid(3, density=0.5)
    for sb in akdtree_partition(grid):
        x, y, z = sb.origin
        dx, dy, dz = sb.bsize
        assert grid.occ[x:x + dx, y:y + dy, z:z + dz].all()


@given(seed=st.integers(0, 5000), density=st.floats(0.3, 0.98))
def test_gsp_roundtrip_restores_zeros(seed, density):
    grid = _random_grid(seed, density=density)
    padded, g = gsp_pad(grid.data, grid.mask, unit=grid.unit)
    # padding only touches empty blocks
    occ_cells = np.repeat(np.repeat(np.repeat(
        g.occ, g.unit, 0), g.unit, 1), g.unit, 2)
    assert (padded[occ_cells] == g.data[occ_cells]).all()
    # unpad restores exact zeros outside
    rec = gsp_unpad(padded, g)
    assert (rec[~occ_cells] == 0).all()
    assert (rec[occ_cells] == g.data[occ_cells]).all()


def test_gsp_pads_with_neighbor_average():
    # one non-empty block with constant value 2.0; its empty face neighbor
    # must be padded with 2.0 in the adjacent m layers
    occ_data = np.zeros((8, 8, 8), np.float32)
    occ_data[0:4] = 2.0
    mask = np.zeros_like(occ_data, bool)
    mask[0:4] = True
    padded, g = gsp_pad(occ_data, mask, unit=4)
    m = min(4 // 2, 4)
    assert np.allclose(padded[4:4 + m, :4, :4], 2.0)


@given(seed=st.integers(0, 5000))
def test_nast_roundtrip(seed):
    grid = _random_grid(seed)
    packed, coords, g = nast_pack(grid.data, grid.mask, unit=grid.unit)
    rec = nast_unpack(packed, coords, g)
    assert (rec == g.data).all()
    assert nast_meta_bits(coords) == coords.shape[0] * 48 + 96
