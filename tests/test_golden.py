"""Golden-file conformance: frozen ``.tacz`` fixtures must keep
decoding bit-identically (ISSUE 9).

The fixtures under ``tests/golden/`` were written once (see
``tests/golden/make_golden.py``) and committed; these tests decode them
with *today's* reader and compare against the stored expected arrays.
Any change to the entropy coder, predictor, payload codecs, container
framing, or frontier parsing that alters decoded bytes — or drops the
ability to read old files — fails here first.
"""
import os

import numpy as np
import pytest

from repro import io as tacz

GOLD = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def expected():
    with np.load(os.path.join(GOLD, "expected.npz")) as z:
        return {k: z[k] for k in z.files}


def _levels(expected):
    return sorted(int(k[len("level"):]) for k in expected
                  if k.startswith("level"))


def _assert_matches(rd, expected):
    lis = _levels(expected)
    assert rd.n_levels == len(lis)
    for li in lis:
        got = np.asarray(rd.read_level(li))
        want = expected[f"level{li}"]
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_golden_v1(expected):
    with tacz.TACZReader(os.path.join(GOLD, "v1.tacz")) as rd:
        assert rd.version == 1
        assert rd.frontier is None and rd.frontier_error is None
        _assert_matches(rd, expected)


def test_golden_v2_zlib(expected):
    with tacz.TACZReader(os.path.join(GOLD, "v2_zlib.tacz")) as rd:
        assert rd.version >= 2
        _assert_matches(rd, expected)
        # the frozen TACF section still parses
        assert rd.frontier_error is None
        dp = rd.frontier.default_point
        assert rd.frontier.metric == "psnr"
        assert dp.metrics["psnr"] == 72.0
        assert rd.frontier.select("psnr>=60") is dp


def test_golden_multipart(expected):
    with tacz.open_snapshot(os.path.join(GOLD, "multipart.taczd")) as rd:
        _assert_matches(rd, expected)
        assert rd.frontier is not None
        assert rd.frontier.default_point.metrics["psnr"] == 72.0


def test_golden_truncated_tacf(expected):
    """The corrupt-frontier fault fixture: the lying TACF body length
    must cost exactly the frontier — the data still decodes bit for
    bit and the error is reported, not raised."""
    with tacz.TACZReader(os.path.join(GOLD, "truncated_tacf.tacz")) as rd:
        assert rd.frontier is None
        assert rd.frontier_error
        _assert_matches(rd, expected)


def test_golden_error_bound(expected):
    """The frozen snapshots honor the eb they were written at (1e-3)."""
    recons = tacz.read(os.path.join(GOLD, "v2_zlib.tacz"))
    for li in _levels(expected):
        mask = expected[f"mask{li}"]
        err = np.abs(recons[li] - expected[f"orig{li}"])[mask]
        if err.size:
            assert float(err.max()) <= 1e-3 * (1 + 1e-5)
