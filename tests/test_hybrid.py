"""End-to-end TAC/TAC+ system behaviour: error bounds through every
strategy, hybrid policy thresholds, SHE accounting, baselines, adaptive eb."""
import numpy as np
import pytest

from repro.core import amr, baselines, hybrid, metrics, she
from repro.core.adaptive_eb import PAPER_RATIOS, level_error_bounds
from repro.core.blocks import make_block_grid, extract_subblock
from repro.core.opst import opst_partition


@pytest.fixture(scope="module")
def ds():
    return amr.synthetic_amr((32, 32, 32), densities=[0.23, 0.77],
                             refine_block=4, seed=10)


@pytest.mark.parametrize("algorithm,she_flag", [
    ("lor_reg", True), ("lor_reg", False), ("interp", False),
    ("lorenzo", False)])
def test_amr_error_bound(ds, algorithm, she_flag):
    eb = 0.05
    res = hybrid.compress_amr(ds, eb=eb, unit=4, algorithm=algorithm,
                              she=she_flag)
    for lvl, lres in zip(ds.levels, res.levels):
        err = np.abs(lres.recon[lvl.mask] - lvl.data[lvl.mask])
        assert err.max() <= eb * (1 + 1e-5), (algorithm, she_flag)
        # empty regions restored as exact zeros
        assert (lres.recon[~lvl.mask] == 0).all()


@pytest.mark.parametrize("strategy", ["gsp", "opst", "akdtree", "nast"])
def test_every_strategy_bounds_error(ds, strategy):
    lvl = ds.levels[0]
    res = hybrid.compress_level(lvl.data, lvl.mask, eb=0.05, unit=4,
                                algorithm="lor_reg", she=True,
                                strategy=strategy)
    err = np.abs(res.recon[lvl.mask] - lvl.data[lvl.mask])
    assert err.max() <= 0.05 * (1 + 1e-5)


def test_hybrid_policy_thresholds():
    assert hybrid.choose_strategy(0.3, algorithm="lor_reg", she=True) == "opst"
    assert hybrid.choose_strategy(0.7, algorithm="lor_reg", she=True) == "akdtree"
    assert hybrid.choose_strategy(0.3, algorithm="interp", she=False) == "opst"
    assert hybrid.choose_strategy(0.7, algorithm="interp", she=False) == "akdtree"
    assert hybrid.choose_strategy(0.9, algorithm="interp", she=False) == "gsp"


def test_per_level_adaptive_eb(ds):
    ebs = level_error_bounds(0.1, ds.n_levels, metric="power_spectrum")
    assert len(ebs) == 2 and ebs[0] == 0.1
    assert abs(ebs[0] / ebs[1] - PAPER_RATIOS["power_spectrum"]) < 1e-6
    res = hybrid.compress_amr(ds, eb=ebs, unit=4)
    for lvl, lres, eb in zip(ds.levels, res.levels, ebs):
        assert np.abs(lres.recon[lvl.mask] - lvl.data[lvl.mask]).max() \
            <= eb * (1 + 1e-5)
    assert abs(level_error_bounds(1.0, 2, metric="halo_finder")[0]
               / level_error_bounds(1.0, 2, metric="halo_finder")[1]
               - PAPER_RATIOS["halo_finder"]) < 1e-6


def test_she_beats_per_block_codebooks(ds):
    """Alg. 4's point: one shared tree vs a tree per block."""
    lvl = ds.levels[0]
    grid = make_block_grid(lvl.data, lvl.mask, unit=4)
    bricks = [extract_subblock(grid, sb) for sb in opst_partition(grid)]
    assert len(bricks) > 10
    shared = she.she_encode(bricks, 0.05, shared=True)
    separate = she.she_encode(bricks, 0.05, shared=False)
    assert shared.codebook_bits < separate.codebook_bits
    assert (shared.payload_bits + shared.codebook_bits
            <= separate.payload_bits + separate.codebook_bits)


def test_baselines_error_bound(ds):
    eb = 0.05
    for res in (baselines.compress_1d_naive(ds, eb),
                baselines.compress_zmesh(ds, eb),
                baselines.compress_3d_baseline(ds, eb)):
        for lvl, lres in zip(ds.levels, res.levels):
            err = np.abs(lres.recon[lvl.mask] - lvl.data[lvl.mask])
            assert err.max() <= eb * (1 + 1e-5), res.method


def test_zmesh_order_is_complete_permutation(ds):
    stream, idx, tags = baselines.zmesh_order(ds)
    assert stream.size == ds.total_values()
    for lvl, ix in zip(ds.levels, idx):
        assert ix.size == lvl.n_valid
        assert np.unique(ix).size == ix.size


def test_compression_accounting_consistency(ds):
    res = hybrid.compress_amr(ds, eb=0.05, unit=4)
    assert res.total_bits == sum(l.total_bits for l in res.levels)
    assert res.compression_ratio() == pytest.approx(
        res.n_values * 32 / res.total_bits)
    assert res.bit_rate() == pytest.approx(res.total_bits / res.n_values)


def test_tiling_and_densities():
    for name in ("run1_z10", "run3_z1", "warpx_800"):
        ds = amr.load_preset(name)
        assert ds.check_tiling()
        target = amr.NYX_LIKE_PRESETS[name]["densities"]
        got = ds.densities()
        for t, g in zip(target, got):
            assert abs(t - g) < 0.05, (name, target, got)
