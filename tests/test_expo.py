"""Exposition round-trip: ``expo.parse(reg.render())`` must reproduce
``reg.snapshot()`` exactly (ISSUE 8).

The property test drives a fresh ``MetricsRegistry`` with
hypothesis-generated families — metric names, label values including
escaping edge cases (backslashes, quotes, newlines), histograms with
``+Inf`` overflow buckets — and asserts the parsed scrape reduces to the
exact ``snapshot()`` dict.  Deterministic tests pin the nasty parser
corners (suffix collisions, escape sequences, malformed input) and the
empty-histogram hardening (clean nulls, never NaN).
"""
import math

import pytest

from repro.obs import expo
from repro.obs.registry import MetricsRegistry, quantile_from_buckets


def _roundtrip(reg: MetricsRegistry) -> None:
    parsed = expo.parse(reg.render())
    assert expo.to_snapshot(parsed) == reg.snapshot()


# ------------------------------ deterministic ------------------------------


def test_roundtrip_counters_gauges_histograms():
    reg = MetricsRegistry()
    c = reg.counter("rt_requests_total", "Requests.", labels=("route",))
    c.labels("/v1/meta").inc(3)
    c.labels("/v1/regions").inc(17.5)
    g = reg.gauge("rt_occupancy", "Occupancy.")
    g.set(-2.25)
    h = reg.histogram("rt_latency_seconds", "Latency.",
                      labels=("stage",), buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.labels("decode").observe(v)
    h.labels("plan")            # declared child, zero observations
    _roundtrip(reg)


def test_roundtrip_label_escaping_edge_cases():
    reg = MetricsRegistry()
    g = reg.gauge("esc_gauge", 'help with "quotes", \\backslash\\ and\n'
                  "a newline", labels=("k",))
    for value in ('plain', 'with"quote', 'back\\slash', 'new\nline',
                  'trailing\\', '\\"mix\n\\', '', 'comma,and{braces}',
                  'le="0.5"'):
        g.labels(value).set(1.5)
    _roundtrip(reg)


def test_roundtrip_inf_and_extreme_values():
    reg = MetricsRegistry()
    g = reg.gauge("ext_gauge", "Extremes.", labels=("case",))
    g.labels("posinf").set(math.inf)
    g.labels("neginf").set(-math.inf)
    g.labels("tiny").set(5e-324)
    g.labels("huge").set(1.7976931348623157e308)
    g.labels("int15").set(1e15)
    _roundtrip(reg)


def test_histogram_suffix_collision_with_exact_family():
    """A counter that merely *ends* in _sum/_count/_bucket next to a
    histogram with the matching base name must not be misattributed."""
    reg = MetricsRegistry()
    h = reg.histogram("col_seconds", "Histogram.", buckets=(0.5,))
    h.observe(0.1)
    reg.counter("col_seconds_count_total", "A counter.").inc(7)
    reg.counter("col_seconds_sum", "Also a counter.").inc(2)
    _roundtrip(reg)
    parsed = expo.parse(reg.render())
    assert parsed["col_seconds"].kind == "histogram"
    assert parsed["col_seconds_sum"].kind == "counter"
    assert parsed["col_seconds_sum"].series[()] == 2.0


def test_parse_histogram_reassembly_and_quantile():
    reg = MetricsRegistry()
    h = reg.histogram("q_seconds", "Q.", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 2.0):
        h.observe(v)
    parsed = expo.parse(reg.render())
    ph = parsed["q_seconds"].series[()]
    assert ph.bounds == (0.1, 1.0)
    assert ph.counts == [1, 2, 1]       # non-cumulative, +Inf last
    assert ph.count == 4 and ph.sum == pytest.approx(3.05)
    # same estimator as the registry histogram
    assert ph.quantile(0.5) == h.quantile(0.5)


def test_parse_get_by_labels_and_timestamps():
    fams = expo.parse(
        "# TYPE t_total counter\n"
        't_total{route="/a"} 3 1700000000000\n'
        't_total{route="/b"} 4\n')
    fam = fams["t_total"]
    assert fam.get(route="/a") == 3.0
    assert fam.get(route="/b") == 4.0
    assert fam.get(route="/c") is None
    assert fam.get(bogus="x") is None


def test_parse_malformed_lines_raise():
    with pytest.raises(ValueError):
        expo.parse("just_a_name_no_value\n")
    with pytest.raises(ValueError):
        expo.parse('bad{unterminated="v\n')
    with pytest.raises(ValueError):        # histogram without +Inf
        expo.parse("# TYPE h histogram\n"
                   'h_bucket{le="1"} 1\nh_sum 1\nh_count 1\n')
    with pytest.raises(ValueError):        # decreasing cumulative counts
        expo.parse("# TYPE h histogram\n"
                   'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n'
                   "h_sum 1\nh_count 3\n")


def test_untyped_samples_and_unknown_comments():
    fams = expo.parse("# EOF whatever\nfree_sample 2.5\n")
    assert fams["free_sample"].kind == "untyped"
    assert fams["free_sample"].series[()] == 2.5


# ------------------------ empty-histogram hardening ------------------------


def test_empty_histogram_quantile_and_mean_are_none():
    reg = MetricsRegistry()
    h = reg.histogram("empty_seconds", "Empty.", buckets=(0.1, 1.0))
    assert h.quantile(0.5) is None
    assert h.quantile(0.0) is None
    assert h.quantile(1.0) is None
    assert h.mean() is None
    h.observe(0.2)
    assert h.quantile(0.5) is not None
    assert h.mean() == pytest.approx(0.2)


def test_quantile_from_buckets_contract():
    assert quantile_from_buckets((0.1, 1.0), [0, 0, 0], 0.99) is None
    assert quantile_from_buckets((), [0], 0.5) is None
    with pytest.raises(ValueError):
        quantile_from_buckets((0.1,), [1, 0], 1.5)
    # all mass in the overflow bucket clamps to the largest finite bound
    assert quantile_from_buckets((0.1, 1.0), [0, 0, 4], 0.99) == 1.0


def test_help_text_with_newline_and_backslash_renders_one_line():
    """The render() edge the round-trip test shook out: unescaped help
    newlines used to corrupt the exposition into malformed lines."""
    reg = MetricsRegistry()
    reg.gauge("nl_gauge", "line one\nline two \\ backslash").set(1)
    text = reg.render()
    lines = [ln for ln in text.splitlines() if ln]
    assert len(lines) == 3                      # HELP, TYPE, sample
    assert "\\n" in lines[0]
    _roundtrip(reg)


# ------------------------------- property ----------------------------------
# Random-registry round trip.  With hypothesis installed the spec is
# drawn (and shrunk) by hypothesis; without it, the same generator runs
# over a sweep of fixed seeds through ``random.Random`` — the property
# holds either way, hypothesis just finds counterexamples faster.


def _build_and_check(spec) -> None:
    reg = MetricsRegistry()
    for name, kind, labels, children, bounds in spec:
        if kind == "counter":
            fam = reg.counter(name, f"help for {name}", labels=labels)
            for values, samples in children:
                child = fam.labels(*values)
                for v in samples:
                    child.inc(abs(v))
        elif kind == "gauge":
            fam = reg.gauge(name, f"help\nfor \\ {name}", labels=labels)
            for values, samples in children:
                child = fam.labels(*values)
                for v in samples:
                    child.set(v)
        else:
            fam = reg.histogram(name, f"help for {name}", labels=labels,
                                buckets=bounds)
            for values, samples in children:
                child = fam.labels(*values)
                for v in samples:
                    child.observe(abs(v))
    _roundtrip(reg)


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _NAME = st.from_regex(r"[a-z][a-z0-9_]{0,15}", fullmatch=True)
    _LABEL_VALUE = st.text(
        alphabet=st.characters(blacklist_categories=("Cs",)),
        max_size=12)
    _FINITE = st.floats(allow_nan=False, allow_infinity=False)

    @st.composite
    def _registry_spec(draw):
        n_fams = draw(st.integers(1, 4))
        names = draw(st.lists(_NAME, min_size=n_fams, max_size=n_fams,
                              unique=True))
        fams = []
        for name in names:
            kind = draw(st.sampled_from(
                ["counter", "gauge", "histogram"]))
            labels = draw(st.lists(
                _NAME.filter(lambda s: s != "le"),
                min_size=0, max_size=2, unique=True))
            children = draw(st.lists(
                st.tuples(
                    st.lists(_LABEL_VALUE, min_size=len(labels),
                             max_size=len(labels)).map(tuple),
                    st.lists(_FINITE, min_size=0, max_size=4)),
                min_size=0, max_size=3,
                unique_by=lambda t: t[0]))
            bounds = tuple(sorted(set(draw(st.lists(
                st.floats(min_value=1e-6, max_value=1e6,
                          allow_nan=False, allow_infinity=False),
                min_size=0, max_size=4)))))
            fams.append((name, kind, tuple(labels), children, bounds))
        return fams

    @settings(max_examples=60, deadline=None)
    @given(spec=_registry_spec())
    def test_property_roundtrip_reproduces_snapshot(spec):
        _build_and_check(spec)

else:
    import random

    # nasty-first corpus the random sweep mixes into label values
    _TRICKY = ['', 'a', 'with"quote', 'back\\slash', 'new\nline',
               'trailing\\', '\\', '\\n', 'le="1"', '{b,r=a}', ' ',
               'unié☃']

    def _random_spec(rng: "random.Random"):
        fams = []
        names = rng.sample(
            [f"fam_{chr(97 + i)}" for i in range(8)], rng.randint(1, 4))
        for name in names:
            kind = rng.choice(["counter", "gauge", "histogram"])
            labels = tuple(rng.sample(["alpha", "beta", "gamma"],
                                      rng.randint(0, 2)))
            children, seen = [], set()
            for _ in range(rng.randint(0, 3)):
                values = tuple(
                    rng.choice(_TRICKY) if rng.random() < 0.7
                    else str(rng.random()) for _ in labels)
                if values in seen:
                    continue
                seen.add(values)
                samples = [rng.uniform(-1e6, 1e6) * 10 ** rng.randint(-9, 9)
                           for _ in range(rng.randint(0, 4))]
                if rng.random() < 0.2:
                    samples.append(float("inf"))
                children.append((values, samples))
            bounds = tuple(sorted({abs(rng.gauss(0, 10)) + 1e-6
                                   for _ in range(rng.randint(0, 4))}))
            fams.append((name, kind, labels, children, bounds))
        return fams

    @pytest.mark.parametrize("seed", range(40))
    def test_property_roundtrip_reproduces_snapshot(seed):
        _build_and_check(_random_spec(random.Random(seed)))
