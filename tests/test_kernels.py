"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(assignment brief deliverable (c))."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _x(shape, seed, dtype=np.float32, scale=10.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(dtype))


@pytest.mark.parametrize("shape,tile", [
    ((8, 128, 128), (8, 128, 128)),
    ((16, 128, 128), (8, 128, 128)),
    ((8, 256, 128), (8, 128, 128)),
    ((16, 256, 256), (8, 128, 128)),
    ((4, 8, 8), (4, 8, 8)),
])
@pytest.mark.parametrize("eb", [0.5, 0.01])
def test_lorenzo3d_codes_vs_ref(shape, tile, eb):
    x = _x(shape, hash((shape, eb)) % 2**31)
    codes_k = ops.lorenzo3d_codes(x, eb=eb, tile=tile)
    codes_r = ref.lorenzo3d_codes_ref(x, eb, tile=tile)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))


@pytest.mark.parametrize("shape,tile", [
    ((8, 128, 128), (8, 128, 128)),
    ((16, 256, 128), (8, 128, 128)),
])
@pytest.mark.parametrize("eb", [0.1])
def test_lorenzo3d_roundtrip_error_bound(shape, tile, eb):
    x = _x(shape, 7)
    codes = ops.lorenzo3d_codes(x, eb=eb, tile=tile)
    recon_k = ops.lorenzo3d_recon(codes, eb=eb, tile=tile)
    recon_r = ref.lorenzo3d_recon_ref(
        ref.lorenzo3d_codes_ref(x, eb, tile=tile), eb, tile=tile)
    np.testing.assert_allclose(np.asarray(recon_k), np.asarray(recon_r),
                               rtol=0, atol=1e-5)
    assert float(jnp.abs(recon_k - x).max()) <= eb * (1 + 1e-5)


@pytest.mark.parametrize("shape,tile", [
    ((5, 8, 128, 128), (8, 128, 128)),
    ((3, 16, 128, 256), (8, 128, 128)),
    ((7, 4, 8, 8), (4, 8, 8)),
    ((2, 8, 8, 8), (8, 128, 128)),
])
@pytest.mark.parametrize("eb", [0.5, 0.01])
def test_lorenzo3d_batched_codes_vs_ref(shape, tile, eb):
    x = _x(shape, hash((shape, eb)) % 2**31)
    codes_k = ops.lorenzo3d_codes_batched(x, eb=eb, tile=tile)
    codes_r = ref.lorenzo3d_codes_batched_ref(x, eb, tile=tile)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))


@pytest.mark.parametrize("shape,tile", [
    ((4, 8, 128, 128), (8, 128, 128)),
    ((6, 4, 8, 8), (4, 8, 8)),
])
def test_lorenzo3d_batched_matches_per_brick(shape, tile):
    """The batch axis must not leak values across bricks: the batched
    kernel equals the 3D kernel run brick-by-brick (SHE's independence)."""
    eb = 0.05
    x = _x(shape, 11)
    codes_b = np.asarray(ops.lorenzo3d_codes_batched(x, eb=eb, tile=tile))
    for i in range(shape[0]):
        codes_i = np.asarray(ops.lorenzo3d_codes(x[i], eb=eb, tile=tile))
        np.testing.assert_array_equal(codes_b[i], codes_i)
    recon_b = ops.lorenzo3d_recon_batched(jnp.asarray(codes_b), eb=eb,
                                          tile=tile)
    recon_r = ref.lorenzo3d_recon_batched_ref(jnp.asarray(codes_b), eb,
                                              tile=tile)
    np.testing.assert_allclose(np.asarray(recon_b), np.asarray(recon_r),
                               rtol=0, atol=1e-5)
    assert float(jnp.abs(recon_b - x).max()) \
        <= eb + float(jnp.abs(x).max()) * 2.0 ** -22


@pytest.mark.parametrize("n,n_bins,chunk", [
    (1000, 64, 256), (8192, 1024, 8192), (5000, 128, 1024), (10, 16, 8)])
def test_hist_vs_ref(n, n_bins, chunk):
    rng = np.random.default_rng(n)
    codes = jnp.asarray(rng.integers(-5, n_bins + 10, size=(n,)), jnp.int32)
    h_k = ops.hist(codes, n_bins=n_bins, chunk=chunk)
    h_r = ref.hist_ref(codes, n_bins)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))
    assert int(h_k.sum()) == n


@pytest.mark.parametrize("shape,group", [
    ((256, 512), 128), ((512, 256), 128), ((64, 128), 64), ((256, 1024), 256)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_group_quant_vs_ref(shape, group, dtype):
    x = _x(shape, hash((shape, group)) % 2**31, dtype=dtype, scale=3.0)
    q_k, s_k = ops.group_quant(x, group=group)
    q_r, s_r = ref.group_quant_ref(x, group)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    d_k = ops.group_dequant(q_k, s_k, group=group)
    d_r = ref.group_dequant_ref(q_r, s_r, group)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-6)
    # int8 quantization error bound: |x - deq| <= scale/2 per group
    err = np.abs(np.asarray(d_k) - np.asarray(x))
    bound = np.repeat(np.asarray(s_k), group, axis=1) * 0.5 + 1e-7
    assert (err <= bound).all()


def test_group_quant_zero_group_exact():
    x = jnp.zeros((256, 256), jnp.float32)
    q, s = ops.group_quant(x, group=128)
    assert (np.asarray(q) == 0).all()
    d = ops.group_dequant(q, s, group=128)
    assert (np.asarray(d) == 0).all()


def test_kernel_codes_match_core_sz_per_brick():
    """The Pallas tile == repro.core per-brick Lorenzo semantics."""
    from repro.core import sz

    x = _x((8, 128, 128), 3)
    eb = 0.05
    codes_k = np.asarray(ops.lorenzo3d_codes(x, eb=eb, tile=(8, 128, 128)))
    codes_c = sz.lorenzo_nd_codes(sz.prequant(np.asarray(x), eb))
    np.testing.assert_array_equal(codes_k, codes_c.astype(np.int32))
