"""Pipeline-wide error-bound property suite (ISSUE 9).

The error bound is the one promise every layer of the pipeline must
preserve: ``max |recon - orig| <= eb`` on every covered cell of every
level, no matter which branch compressed it, which entropy engine
decoded it, which container codec framed it, or which serving path
delivered it.  This module asserts that promise *end to end* — original
array → compress → TACZ write → (reader | region server | sharded
router) → reconstruction — across:

  * branches: ``lorenzo`` / ``interp`` / ``lor_reg`` (adaptive lor+reg);
  * entropy engines: ``numpy`` / ``batched`` decode paths;
  * container codecs: v1 (pre-codec) containers and v2 with
    ``none``/``zlib``/``auto`` payload passes;
  * single-file ``.tacz`` and multi-part ``.taczd`` snapshots;
  * cold ``TACZReader`` reads, warm ``RegionServer`` reads (cache hit
    path included), and scatter-gathered ``ShardedRegionRouter`` reads;

plus the rate–distortion sanity property the autotuner builds on:
loosening the bound never costs bits.

Quantization maps each value to ``round(x / (2 eb))``-style bins, so
the decoded error can exceed the nominal bound only by float32
round-off; ``_EB_SLACK`` covers exactly that.
"""
import os
import threading

import numpy as np
import pytest

from repro import io as tacz
from repro.core import amr, hybrid
from repro.io import writer as tacz_writer
from repro.serving import (RegionClient, RegionServer, ShardMap,
                           ShardedRegionRouter, serve)

#: multiplicative slack for float32 round-off on top of the nominal eb
_EB_SLACK = 1.0 + 1e-5

WHOLE = ((0, 32), (0, 32), (0, 32))


def _dataset(seed=5, densities=(0.35, 0.65), shape=(32, 32, 32)):
    return amr.synthetic_amr(tuple(shape), densities=list(densities),
                             refine_block=4, seed=seed)


def _assert_within_eb(ds, recons, ebs):
    """Every covered cell of every level is within its level's bound."""
    assert len(recons) == len(ds.levels)
    for li, (lvl, recon) in enumerate(zip(ds.levels, recons)):
        err = np.abs(np.asarray(recon) - lvl.data)[lvl.mask]
        if err.size:
            assert float(err.max()) <= ebs[li] * _EB_SLACK, \
                f"level {li}: {err.max():g} > eb {ebs[li]:g}"


def _eb_for(ds, rel=1e-3):
    lvl = ds.levels[0]
    return rel * float(lvl.data.max() - lvl.data.min())


def _compress(ds, eb, algorithm="lor_reg"):
    """Serializable compression for any branch: the non-SHE branches
    (pure lorenzo / interp) are only indexable through the gsp
    whole-level strategy, which conveniently also exercises the
    WHOLE_LEVEL decode path."""
    strategy = None if algorithm == "lor_reg" else "gsp"
    return hybrid.compress_amr(ds, eb=eb, algorithm=algorithm,
                               strategy=strategy)


# ------------------------- branch × codec matrix ---------------------------


@pytest.mark.parametrize("algorithm", ["lorenzo", "interp", "lor_reg"])
@pytest.mark.parametrize("codec", ["none", "zlib", "auto"])
def test_eb_end_to_end_single_file(tmp_path, algorithm, codec):
    ds = _dataset()
    eb = _eb_for(ds)
    res = _compress(ds, eb, algorithm)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res, payload_codec=codec)
    recons = tacz.read(path)
    _assert_within_eb(ds, recons, [lr.eb for lr in res.levels])


@pytest.mark.parametrize("algorithm", ["lorenzo", "lor_reg"])
def test_eb_end_to_end_multipart(tmp_path, algorithm):
    ds = _dataset()
    eb = _eb_for(ds)
    res = _compress(ds, eb, algorithm)
    path = os.path.join(str(tmp_path), "s.taczd")
    tacz.write_multipart(path, res, parts=2)
    with tacz.open_snapshot(path) as rd:
        recons = [rd.read_level(li) for li in range(rd.n_levels)]
    _assert_within_eb(ds, recons, [lr.eb for lr in res.levels])


def test_eb_v1_container(tmp_path):
    """v1 containers (no payload-codec pass) preserve the bound too."""
    ds = _dataset()
    eb = _eb_for(ds)
    res = hybrid.compress_amr(ds, eb=eb)
    packed = [tacz_writer.pack_level(lr, payload_codec="none")
              for lr in res.levels]
    blob = tacz_writer.build_container(packed, version=1)
    path = os.path.join(str(tmp_path), "v1.tacz")
    with open(path, "wb") as f:
        f.write(blob)
    with tacz.TACZReader(path) as rd:
        assert rd.version == 1
        recons = [rd.read_level(li) for li in range(rd.n_levels)]
    _assert_within_eb(ds, recons, [lr.eb for lr in res.levels])


@pytest.mark.parametrize("engine", ["numpy", "batched"])
def test_eb_entropy_engines(tmp_path, engine):
    ds = _dataset()
    eb = _eb_for(ds)
    res = hybrid.compress_amr(ds, eb=eb)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res)
    with tacz.TACZReader(path, entropy_engine=engine) as rd:
        recons = [rd.read_level(li) for li in range(rd.n_levels)]
    _assert_within_eb(ds, recons, [lr.eb for lr in res.levels])


def test_eb_per_level_vector(tmp_path):
    """A per-level eb vector (the autotuner's output form) is honored
    level by level — each level meets *its own* bound."""
    ds = _dataset(densities=(0.3, 0.5, 0.2))
    base = _eb_for(ds)
    ebs = [base * 0.5, base * 2.0, base * 8.0]
    res = hybrid.compress_amr(ds, eb=ebs)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res)
    _assert_within_eb(ds, tacz.read(path), ebs)


# ----------------------------- serving paths -------------------------------


def test_eb_region_server_cold_and_warm(tmp_path):
    """Cold (first) and warm (cache-hit) RegionServer reads both honor
    the bound — and are bit-identical to each other."""
    ds = _dataset()
    eb = _eb_for(ds)
    res = hybrid.compress_amr(ds, eb=eb)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res)
    with RegionServer(path, cache_bytes=32 << 20) as rs:
        cold = rs.get_roi(WHOLE)
        warm = rs.get_roi(WHOLE)
        _assert_within_eb(ds, [r.data for r in cold],
                          [lr.eb for lr in res.levels])
        for c, w in zip(cold, warm):
            np.testing.assert_array_equal(c.data, w.data)
        assert rs.cache.stats()["hits"] > 0


def test_eb_through_sharded_router(tmp_path):
    """A scatter-gathered read over a two-shard HTTP fleet honors the
    bound and matches the unsharded server bit for bit."""
    ds = _dataset()
    eb = _eb_for(ds)
    res = hybrid.compress_amr(ds, eb=eb)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res)
    smap = ShardMap(["s0", "s1"], seed=3)
    servers, urls = [], {}
    try:
        for sid in smap.shards:
            httpd = serve(path, port=0, cache_bytes=16 << 20,
                          shard_map=smap, shard_id=sid)
            threading.Thread(target=httpd.serve_forever,
                             daemon=True).start()
            servers.append(httpd)
            urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"
        with RegionServer(path) as single, \
                ShardedRegionRouter(path, smap, urls,
                                    local_fallback=False) as router:
            routed = router.get_roi(WHOLE)
            _assert_within_eb(ds, [r.data for r in routed],
                              [lr.eb for lr in res.levels])
            for g, r in zip(routed, single.get_roi(WHOLE)):
                np.testing.assert_array_equal(g.data, r.data)
    finally:
        for httpd in servers:
            httpd.shutdown()
            httpd.server_close()
            httpd.region_server.close()


def test_eb_http_single_level_roi(tmp_path):
    """The raw <f4 wire format does not disturb the bound on a crop."""
    ds = _dataset()
    eb = _eb_for(ds)
    res = hybrid.compress_amr(ds, eb=eb)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res)
    httpd = serve(path, port=0)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    try:
        cli = RegionClient(f"http://127.0.0.1:{httpd.server_address[1]}")
        box = ((4, 20), (8, 24), (0, 16))
        roi = cli.region(0, box)
        lvl = ds.levels[0]
        sl = tuple(slice(lo, hi) for lo, hi in roi.box)
        err = np.abs(roi.data - lvl.data[sl])[lvl.mask[sl]]
        assert float(err.max()) <= res.levels[0].eb * _EB_SLACK
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


# ------------------------ rate–distortion sanity ---------------------------


def test_rate_distortion_monotonic():
    """Loosening the bound never costs bits, and the achieved error
    tracks the bound — the property the autotuner's search relies on."""
    ds = _dataset()
    base = _eb_for(ds)
    bits, errs = [], []
    for k in (0.25, 1.0, 4.0, 16.0):
        res = hybrid.compress_amr(ds, eb=base * k)
        bits.append(res.total_bits)
        worst = 0.0
        for lvl, lr in zip(ds.levels, res.levels):
            err = np.abs(lr.recon - lvl.data)[lvl.mask]
            if err.size:
                worst = max(worst, float(err.max()))
        errs.append(worst)
    assert all(b2 <= b1 for b1, b2 in zip(bits, bits[1:])), bits
    assert all(e <= base * k * _EB_SLACK
               for e, k in zip(errs, (0.25, 1.0, 4.0, 16.0)))


# --------------------------- hypothesis sweeps ------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("error_bound", max_examples=10,
                              deadline=None)
    settings.load_profile("error_bound")
except ImportError:        # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 10_000),
           eb_rel=st.floats(1e-4, 0.2),
           fine=st.floats(0.1, 0.9),
           algorithm=st.sampled_from(["lorenzo", "interp", "lor_reg"]))
    def test_property_eb_holds_across_seeds(tmp_path_factory, seed,
                                            eb_rel, fine, algorithm):
        ds = amr.synthetic_amr((16, 16, 16),
                               densities=[fine, 1.0 - fine],
                               refine_block=4, seed=seed)
        eb = _eb_for(ds, rel=eb_rel)
        res = _compress(ds, eb, algorithm)
        path = os.path.join(str(tmp_path_factory.mktemp("eb")), "p.tacz")
        tacz.write(path, res)
        _assert_within_eb(ds, tacz.read(path),
                          [lr.eb for lr in res.levels])

    @given(seed=st.integers(0, 10_000),
           lo=st.tuples(st.integers(0, 28), st.integers(0, 28),
                        st.integers(0, 28)),
           ext=st.tuples(st.integers(1, 32), st.integers(1, 32),
                         st.integers(1, 32)))
    def test_property_eb_holds_on_served_crops(tmp_path_factory, seed,
                                               lo, ext):
        ds = amr.synthetic_amr((32, 32, 32), densities=[0.35, 0.65],
                               refine_block=4, seed=seed)
        eb = _eb_for(ds)
        res = hybrid.compress_amr(ds, eb=eb)
        path = os.path.join(str(tmp_path_factory.mktemp("eb")), "p.tacz")
        tacz.write(path, res)
        box = tuple((int(l), int(min(l + e, 32)))
                    for l, e in zip(lo, ext))
        with RegionServer(path, cache_bytes=8 << 20) as rs:
            for roi in rs.get_roi(box):
                lvl = ds.levels[roi.level]
                sl = tuple(slice(b0, b1) for b0, b1 in roi.box)
                err = np.abs(roi.data - lvl.data[sl])[lvl.mask[sl]]
                if err.size:
                    assert float(err.max()) <= \
                        res.levels[roi.level].eb * _EB_SLACK
