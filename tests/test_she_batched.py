"""Batched SHE pipeline vs the sequential reference oracle.

The contract (ISSUE 1 acceptance): for any brick set, ``batched=True`` must
produce **bit-identical** sizes, code streams, and reconstructions to the
sequential per-brick path, and the error bound must hold elementwise.
Deterministic parametrized cases run everywhere; hypothesis sweeps run when
the optional dep is installed (CI always has it).
"""
import numpy as np
import pytest

from repro.core import amr, she
from repro.core.akdtree import akdtree_partition
from repro.core.blocks import extract_subblock, make_block_grid
from repro.core.opst import opst_partition
from repro.core.sz import compress_lor_reg, compress_lor_reg_batched


def _bound(eb, x):
    return eb + np.abs(x).max() * 2.0 ** -22


def _assert_she_identical(a: she.SHEResult, b: she.SHEResult):
    assert a.payload_bits == b.payload_bits
    assert a.codebook_bits == b.codebook_bits
    assert a.meta_bits == b.meta_bits
    assert a.total_bits == b.total_bits
    for ra, rb in zip(a.results, b.results):
        np.testing.assert_array_equal(ra.codes, rb.codes)
        np.testing.assert_array_equal(ra.recon, rb.recon)
        assert ra.payload_bits == rb.payload_bits
        assert ra.meta_bits == rb.meta_bits
        assert ra.extras.get("branch") == rb.extras.get("branch")


def _random_bricks(seed, n, shapes, scale=10.0):
    rng = np.random.default_rng(seed)
    return [(rng.standard_normal(shapes[i % len(shapes)]) * scale)
            .astype(np.float32) for i in range(n)]


# ------------------------- preset-dataset identity --------------------------


@pytest.mark.parametrize("partition", [akdtree_partition, opst_partition])
@pytest.mark.parametrize("eb", [0.05, 1e-3])
def test_batched_matches_sequential_on_amr(partition, eb):
    ds = amr.synthetic_amr((48, 48, 48), densities=[0.23, 0.77],
                           refine_block=4, seed=10)
    lvl = ds.levels[0]
    grid = make_block_grid(lvl.data, lvl.mask, unit=4)
    bricks = [extract_subblock(grid, sb) for sb in partition(grid)]
    assert len(bricks) > 100   # the many-small-blocks regime SHE targets
    seq = she.she_encode(bricks, eb, shared=True, batched=False)
    bat = she.she_encode(bricks, eb, shared=True, batched=True)
    _assert_she_identical(seq, bat)
    for brk, r in zip(bricks, bat.results):
        assert np.abs(r.recon - brk).max() <= _bound(eb, brk)


def test_batched_mixed_shapes_and_singletons():
    """Shape groups of size 1, thin bricks, and cubes all agree."""
    bricks = _random_bricks(0, 13, [(4, 4, 4), (8, 4, 4), (4, 12, 8),
                                    (1, 4, 4), (6, 6, 6)])
    for eb in (0.5, 1e-2):
        seq = she.she_encode(bricks, eb, shared=True, batched=False)
        bat = she.she_encode(bricks, eb, shared=True, batched=True)
        _assert_she_identical(seq, bat)


def test_batched_empty_and_single_brick():
    assert she.she_encode([], 0.1, batched=True).total_bits == \
        she.she_encode([], 0.1, batched=False).total_bits
    bricks = _random_bricks(1, 1, [(6, 6, 6)])
    _assert_she_identical(she.she_encode(bricks, 0.1, batched=False),
                          she.she_encode(bricks, 0.1, batched=True))


def test_batched_4d_bricks_fall_back_to_oracle():
    rng = np.random.default_rng(2)
    bricks = [rng.standard_normal((2, 4, 4, 4)).astype(np.float32),
              rng.standard_normal((4, 4, 4)).astype(np.float32)]
    _assert_she_identical(she.she_encode(bricks, 0.05, batched=False),
                          she.she_encode(bricks, 0.05, batched=True))


def test_pallas_histogram_engine_matches_numpy():
    bricks = _random_bricks(3, 8, [(6, 6, 6)], scale=2.0)
    a = she.she_encode(bricks, 0.05, batched=True, hist_engine="numpy")
    b = she.she_encode(bricks, 0.05, batched=True, hist_engine="pallas")
    _assert_she_identical(a, b)


def test_aggregate_histogram_equals_unique():
    rng = np.random.default_rng(4)
    codes = rng.integers(-300, 300, size=5000)
    s_np, f_np = she.aggregate_histogram(codes, engine="numpy")
    s_u, f_u = np.unique(codes, return_counts=True)
    np.testing.assert_array_equal(s_np, s_u)
    np.testing.assert_array_equal(f_np, f_u)
    s_pl, f_pl = she.aggregate_histogram(codes, engine="pallas")
    np.testing.assert_array_equal(s_pl, s_u)
    np.testing.assert_array_equal(f_pl, f_u)
    # outlier-widened spans must fall back off the one-hot kernel instead
    # of materializing a (chunk, span) tile
    wide = np.concatenate([codes, [10_000_000]])
    s_w, f_w = she.aggregate_histogram(wide, engine="pallas")
    s_wu, f_wu = np.unique(wide, return_counts=True)
    np.testing.assert_array_equal(s_w, s_wu)
    np.testing.assert_array_equal(f_w, f_wu)


# -------------------- batched Lor/Reg compressor oracle ---------------------


@pytest.mark.parametrize("shape", [(4, 4, 4), (8, 8, 8), (13, 7, 9),
                                   (12, 12, 12), (2, 2, 2)])
@pytest.mark.parametrize("eb", [0.5, 1e-2])
def test_lor_reg_batched_is_bit_identical(shape, eb):
    rng = np.random.default_rng(hash((shape, eb)) % 2**31)
    # mix smooth ramps (regression-friendly) and noise (Lorenzo-friendly)
    i, j, k = np.mgrid[0:shape[0], 0:shape[1], 0:shape[2]].astype(np.float32)
    stack = np.stack(
        [3.0 * i + 2.0 * j - k + rng.normal(scale=3 * eb, size=shape)
         .astype(np.float32) for _ in range(3)]
        + [(rng.standard_normal(shape) * 10).astype(np.float32)
           for _ in range(3)])
    batched = compress_lor_reg_batched(stack, eb, block=4)
    for idx in range(stack.shape[0]):
        ref = compress_lor_reg(stack[idx], eb, block=4, count_entropy=False)
        np.testing.assert_array_equal(batched[idx].codes, ref.codes)
        np.testing.assert_array_equal(batched[idx].recon, ref.recon)
        assert batched[idx].meta_bits == ref.meta_bits
        assert batched[idx].extras["branch"] == ref.extras["branch"]
        assert np.abs(batched[idx].recon - stack[idx]).max() \
            <= _bound(eb, stack[idx])


# ------------------- Pallas-kernel Lorenzo branch (ROADMAP) -----------------
#
# On a TPU backend `engine="auto"` routes the batched Lorenzo branch through
# the Pallas kernel; on CPU the kernel runs in interpret mode, so forcing
# `engine="pallas"` here exercises the exact routing.  Inputs are chosen on
# the quantization lattice (x = k·2eb) so the kernel's float32 arithmetic
# agrees exactly with the float64 numpy oracle.


def _lattice_stack(seed, n, shape, eb):
    rng = np.random.default_rng(seed)
    return (rng.integers(-50, 50, size=(n,) + shape) * (2.0 * eb)
            ).astype(np.float32)


@pytest.mark.parametrize("shape", [(8, 8, 8), (4, 4, 4), (16, 16, 16),
                                   (13, 7, 9)])
def test_pallas_engine_matches_numpy_oracle(shape):
    """tile == brick, so any VMEM-sized brick shape routes through the
    kernel — including non-power-of-two ones like (13, 7, 9)."""
    eb = 0.25
    stack = _lattice_stack(11, 5, shape, eb)
    ref = compress_lor_reg_batched(stack, eb, block=4, engine="numpy")
    pal = compress_lor_reg_batched(stack, eb, block=4, engine="pallas")
    for r, p in zip(ref, pal):
        np.testing.assert_array_equal(r.codes, p.codes)
        np.testing.assert_array_equal(r.recon, p.recon)
        assert r.extras["branch"] == p.extras["branch"]
        assert r.meta_bits == p.meta_bits


def test_pallas_engine_falls_back_on_wide_dynamic_range():
    """|x|/(2eb) beyond float32-exact integers would break the error bound
    in the kernel's float32/int32 arithmetic — must fall back to numpy."""
    eb = 1e-4
    rng = np.random.default_rng(14)
    stack = (rng.standard_normal((2, 8, 8, 8)) * 1e4).astype(np.float32)
    assert float(np.abs(stack).max()) / (2 * eb) >= 2 ** 23
    ref = compress_lor_reg_batched(stack, eb, engine="numpy")
    pal = compress_lor_reg_batched(stack, eb, engine="pallas")
    for r, p in zip(ref, pal):
        np.testing.assert_array_equal(r.codes, p.codes)
        np.testing.assert_array_equal(r.recon, p.recon)


def test_pallas_engine_falls_back_on_oversize_brick():
    """A brick bigger than the kernel's VMEM tile budget must fall back to
    the numpy path and still match the oracle exactly."""
    eb = 0.25
    stack = _lattice_stack(12, 1, (16, 128, 128), eb)  # > 8·128·128 cells
    ref = compress_lor_reg_batched(stack, eb, engine="numpy")
    pal = compress_lor_reg_batched(stack, eb, engine="pallas")
    for r, p in zip(ref, pal):
        np.testing.assert_array_equal(r.codes, p.codes)
        np.testing.assert_array_equal(r.recon, p.recon)


def test_engine_auto_uses_numpy_off_tpu():
    """No TPU attached in CI → auto must be the bit-exact host path."""
    import jax

    assert jax.default_backend() != "tpu"
    stack = (np.random.default_rng(13).standard_normal((3, 6, 6, 6)) * 10
             ).astype(np.float32)
    auto = compress_lor_reg_batched(stack, 1e-2, engine="auto")
    ref = compress_lor_reg_batched(stack, 1e-2, engine="numpy")
    for a, r in zip(auto, ref):
        np.testing.assert_array_equal(a.codes, r.codes)
        np.testing.assert_array_equal(a.recon, r.recon)


def test_engine_rejects_unknown():
    with pytest.raises(ValueError, match="engine"):
        compress_lor_reg_batched(np.zeros((1, 4, 4, 4), np.float32), 0.1,
                                 engine="cuda")


# --------------------------- hypothesis sweeps ------------------------------
#
# Guarded (not importorskip'd at module level) so the deterministic cases
# above still run in environments without the optional hypothesis dep.

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:        # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 10_000),
           eb=st.floats(1e-3, 1.0),
           n=st.integers(1, 24),
           shapes=st.sampled_from([[(4, 4, 4)], [(8, 8, 8), (4, 4, 4)],
                                   [(5, 9, 4), (4, 4, 8), (6, 6, 6)]]))
    def test_property_batched_she_identical(seed, eb, n, shapes):
        bricks = _random_bricks(seed, n, shapes)
        seq = she.she_encode(bricks, eb, shared=True, batched=False)
        bat = she.she_encode(bricks, eb, shared=True, batched=True)
        _assert_she_identical(seq, bat)
        for brk, r in zip(bricks, bat.results):
            assert np.abs(r.recon - brk).max() <= _bound(eb, brk)

    @given(seed=st.integers(0, 10_000), eb=st.floats(1e-3, 1.0),
           shape=st.sampled_from([(4, 4, 4), (8, 8, 8), (13, 7, 9)]))
    def test_property_lor_reg_batched_identical(seed, eb, shape):
        rng = np.random.default_rng(seed)
        stack = (rng.standard_normal((4,) + shape) * 10).astype(np.float32)
        batched = compress_lor_reg_batched(stack, eb, block=4)
        for idx in range(4):
            ref = compress_lor_reg(stack[idx], eb, block=4,
                                   count_entropy=False)
            np.testing.assert_array_equal(batched[idx].codes, ref.codes)
            np.testing.assert_array_equal(batched[idx].recon, ref.recon)
            assert batched[idx].extras["branch"] == ref.extras["branch"]
