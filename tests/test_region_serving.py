"""Region serving over TACZ (ISSUE 3): cache, planner, server, HTTP.

The contract:

  * ``RegionServer.get_region/get_roi`` are **bit-identical** to
    ``TACZReader.read_roi`` — cold cache, warm cache, and under
    concurrent access;
  * the ``SubBlockCache`` honors its byte budget with LRU eviction and
    truthful hit/miss/eviction counters;
  * the planner dedupes overlapping boxes down to unique sub-blocks and
    batch-decodes only cache misses;
  * the HTTP endpoint + client round-trip regions exactly, and a
    republished snapshot hot-swaps via the footer CRC.
"""
import os
import threading

import numpy as np
import pytest

from repro import io as tacz
from repro.core import amr, hybrid
from repro.serving.client import RegionClient
from repro.serving.http_api import serve
from repro.serving.regions import DecodePlanner, RegionServer, SubBlockCache

BOXES = [((0, 8), (0, 8), (0, 8)),
         ((5, 23), (11, 40), (2, 9)),
         ((56, 64), (48, 64), (0, 64)),
         ((0, 64), (0, 64), (0, 64)),
         ((30, 34), (30, 34), (30, 34))]


@pytest.fixture(scope="module")
def snapshot(make_amr_snapshot):
    snap = make_amr_snapshot(preset="run1_z10", name="s")
    return snap.path, snap.res


def _assert_same_roi(got, ref):
    assert len(got) == len(ref)
    for g, r in zip(got, ref):
        assert (g.level, g.ratio, g.box) == (r.level, r.ratio, r.box)
        np.testing.assert_array_equal(g.data, r.data)


# ------------------------------- cache --------------------------------------


def test_cache_lru_eviction_under_byte_budget():
    kb = np.zeros(256, dtype=np.float32)          # 1 KiB per brick
    cache = SubBlockCache(budget_bytes=3 * kb.nbytes)
    for i in range(3):
        cache.put((0, i), kb)
    assert len(cache) == 3 and cache.evictions == 0
    assert cache.get((0, 0)) is not None          # 0 is now MRU
    cache.put((0, 3), kb)                         # evicts LRU = 1
    assert cache.evictions == 1
    assert (0, 1) not in cache
    assert (0, 0) in cache and (0, 2) in cache and (0, 3) in cache
    assert cache.nbytes <= cache.budget_bytes
    # counters are truthful
    assert cache.get((0, 1)) is None
    assert cache.stats()["misses"] == 1
    assert cache.stats()["hits"] == 1


def test_cache_rejects_oversized_entry_and_replaces_in_place():
    small = np.zeros(8, dtype=np.float32)
    cache = SubBlockCache(budget_bytes=64)
    cache.put((0, 0), small)                      # 32 B, fits
    big = np.zeros(1024, dtype=np.float32)        # 4 KiB > budget
    cache.put((0, 1), big)
    assert (0, 1) not in cache                    # cannot be held ...
    assert (0, 0) in cache                        # ... and no hot-set flush
    assert cache.evictions == 0
    assert cache.nbytes <= cache.budget_bytes
    # same-key replace updates byte accounting instead of double counting
    cache.put((0, 0), small)
    assert cache.nbytes == small.nbytes


def test_cached_bricks_are_read_only(snapshot):
    path, _ = snapshot
    with RegionServer(path, cache_bytes=1 << 20) as srv:
        srv.get_roi(BOXES[0])
        brick = next(iter(srv.cache._od.values()))
        with pytest.raises((ValueError, RuntimeError)):
            brick[0] = 1.0


# --------------------------- server vs read_roi -----------------------------


def test_get_roi_bit_identical_cold_and_warm(snapshot):
    path, _ = snapshot
    with tacz.TACZReader(path) as rd, \
            RegionServer(path, cache_bytes=64 << 20) as srv:
        for box in BOXES:
            _assert_same_roi(srv.get_roi(box), rd.read_roi(box))   # cold-ish
        cold = srv.cache.stats()
        for box in BOXES:
            _assert_same_roi(srv.get_roi(box), rd.read_roi(box))   # warm
        warm = srv.cache.stats()
        assert warm["hits"] > cold["hits"]
        assert warm["misses"] == cold["misses"]   # nothing re-decoded


def test_get_region_single_level(snapshot):
    path, _ = snapshot
    with tacz.TACZReader(path) as rd, RegionServer(path) as srv:
        for li in range(rd.n_levels):
            roi = srv.get_region(li, BOXES[1])
            ref = rd.read_roi(BOXES[1])[li]
            assert roi.level == li
            np.testing.assert_array_equal(roi.data, ref.data)


def test_empty_and_out_of_range_boxes(snapshot):
    path, _ = snapshot
    with tacz.TACZReader(path) as rd, RegionServer(path) as srv:
        box = ((200, 300), (0, 8), (0, 8))        # beyond the extent
        _assert_same_roi(srv.get_roi(box), rd.read_roi(box))
        for roi in srv.get_roi(box):
            assert roi.data.size == 0


def test_planner_dedupes_overlapping_boxes(snapshot):
    path, _ = snapshot
    boxes = [((0, 16), (0, 16), (0, 16)),
             ((8, 24), (8, 24), (8, 24)),
             ((4, 20), (4, 20), (4, 20))]         # heavy overlap
    with RegionServer(path, cache_bytes=64 << 20) as srv:
        planner = DecodePlanner(srv.reader)
        plans = planner.plan([(li, b) for b in boxes
                              for li in range(srv.n_levels)])
        unique = {k for p in plans for k in p.keys()}
        srv.get_regions(boxes)
        s = srv.cache.stats()
        # one decode per unique sub-block, not per box×sub-block pair
        assert s["misses"] == len(unique)
        assert s["entries"] == len(unique)
        # a repeat batch is all hits
        srv.get_regions(boxes)
        assert srv.cache.stats()["misses"] == len(unique)


def test_batched_group_decode_matches_serial(snapshot):
    """The planner's decode_codes_batched groups must reproduce the
    reader's serial per-brick decode bit-identically."""
    path, _ = snapshot
    with tacz.TACZReader(path) as rd, RegionServer(path) as srv:
        box = ((0, 64), (0, 64), (0, 64))
        srv.get_roi(box)                           # fills cache via batches
        for li, e in enumerate(rd.levels):
            if e.strategy not in tacz.TACZReader._SHE_STRATEGIES:
                continue
            for sbi, sb in enumerate(e.subblocks):
                cached = srv.cache.get((srv.snapshot_crc, li, sbi))
                assert cached is not None
                serial = rd._decode_subblock(li, sb, sb.size)
                np.testing.assert_array_equal(cached, serial)


def test_tight_budget_still_bit_identical(snapshot):
    """Eviction thrash must never affect results, only speed."""
    path, _ = snapshot
    with tacz.TACZReader(path) as rd, \
            RegionServer(path, cache_bytes=4096) as srv:
        for box in BOXES[:3]:
            _assert_same_roi(srv.get_roi(box), rd.read_roi(box))
        assert srv.cache.stats()["evictions"] > 0


# ------------------------------ concurrency ---------------------------------


def test_threaded_get_region_stress(snapshot):
    path, _ = snapshot
    rng = np.random.default_rng(0)
    boxes = []
    for _ in range(12):
        lo = rng.integers(0, 48, size=3)
        ext = rng.integers(1, 17, size=3)
        boxes.append(tuple((int(l), int(l + e)) for l, e in zip(lo, ext)))
    with tacz.TACZReader(path) as rd:
        refs = {b: rd.read_roi(b) for b in boxes}
    errors: list[BaseException] = []
    with RegionServer(path, cache_bytes=1 << 20) as srv:
        def worker(seed):
            try:
                order = np.random.default_rng(seed).permutation(len(boxes))
                for i in order:
                    _assert_same_roi(srv.get_roi(boxes[i]), refs[boxes[i]])
            except BaseException as exc:   # surfaces in the main thread
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(s,))
                   for s in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not any(t.is_alive() for t in threads)
    if errors:
        raise errors[0]


# ----------------------------- HTTP endpoint --------------------------------


@pytest.fixture()
def endpoint(snapshot):
    path, res = snapshot
    httpd = serve(path, port=0, cache_bytes=64 << 20)
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    client = RegionClient(f"http://127.0.0.1:{httpd.server_address[1]}")
    client.httpd = httpd               # exposed for fault-injection tests
    try:
        yield client, path, res
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


def test_http_meta_and_stats(endpoint):
    client, path, res = endpoint
    meta = client.meta()
    assert len(meta["levels"]) == len(res.levels)
    assert meta["levels"][0]["shape"] == list(res.levels[0].recon.shape)
    with tacz.TACZReader(path) as rd:
        assert meta["snapshot_crc"] == rd.index_crc
    assert "hits" in client.stats()


def test_http_region_roundtrip(endpoint):
    client, path, _ = endpoint
    with tacz.TACZReader(path) as rd:
        for box in BOXES[:3]:
            ref = rd.read_roi(box)
            for li in range(rd.n_levels):
                roi = client.region(li, box)
                assert (roi.level, roi.ratio, roi.box) == \
                    (ref[li].level, ref[li].ratio, ref[li].box)
                np.testing.assert_array_equal(roi.data, ref[li].data)


def test_http_batched_regions_roundtrip(endpoint):
    client, path, _ = endpoint
    with tacz.TACZReader(path) as rd:
        refs = [rd.read_roi(b) for b in BOXES[:3]]
    got = client.regions(BOXES[:3])
    for per_box, ref in zip(got, refs):
        _assert_same_roi(per_box, ref)
    # level-filtered batch
    got = client.regions(BOXES[:2], levels=[1])
    for per_box, ref in zip(got, refs):
        assert len(per_box) == 1
        np.testing.assert_array_equal(per_box[0].data, ref[1].data)


def test_http_bad_requests(endpoint):
    import urllib.error
    client, _, _ = endpoint
    for path in ["/v1/region?level=99&box=0:8,0:8,0:8",
                 "/v1/region?level=-1&box=0:8,0:8,0:8",
                 "/v1/region?level=0&box=nope",
                 "/nope"]:
        with pytest.raises(urllib.error.HTTPError):
            client._get(path).read()
    # batched route must 400 (not reset the connection) on bad levels
    for bad in ([99], [-1]):
        with pytest.raises(urllib.error.HTTPError) as exc:
            client.regions([((0, 8), (0, 8), (0, 8))], levels=bad)
        assert exc.value.code == 400


def test_http_decode_failure_returns_500_not_reset(endpoint):
    """A decode-side exception must surface as an HTTP error response,
    not a dead handler thread and a dropped connection."""
    import urllib.error
    client, _, _ = endpoint
    rs = client.httpd.region_server
    orig = rs.get_regions_with_crc
    rs.get_regions_with_crc = lambda *a, **kw: (_ for _ in ()).throw(
        IOError("injected payload corruption"))
    try:
        with pytest.raises(urllib.error.HTTPError) as exc:
            client.region(0, BOXES[0])
        assert exc.value.code == 500
        with pytest.raises(urllib.error.HTTPError) as exc:
            client.regions([BOXES[0]])
        assert exc.value.code == 500
    finally:
        rs.get_regions_with_crc = orig
    np.testing.assert_array_equal(                 # endpoint still serves
        client.region(0, BOXES[0]).data,
        client.regions([BOXES[0]])[0][0].data)


def test_get_regions_rejects_bad_levels(snapshot):
    path, _ = snapshot
    with RegionServer(path) as srv:
        with pytest.raises(ValueError, match="out of range"):
            srv.get_regions([BOXES[0]], levels=[srv.n_levels])
        with pytest.raises(ValueError, match="out of range"):
            srv.get_region(-1, BOXES[0])


# ------------------------------- hot swap -----------------------------------


def test_snapshot_hot_swap_via_footer_crc(tmp_path):
    ds_a = amr.synthetic_amr((32, 32, 32), densities=[0.23, 0.77],
                             refine_block=4, seed=1)
    ds_b = amr.synthetic_amr((32, 32, 32), densities=[0.4, 0.6],
                             refine_block=4, seed=9)
    res_a = hybrid.compress_amr(ds_a, eb=1e-3)
    res_b = hybrid.compress_amr(ds_b, eb=1e-3)
    path = os.path.join(str(tmp_path), "hot.tacz")
    tacz.write(path, res_a)
    box = ((0, 16), (0, 16), (0, 16))
    with RegionServer(path, cache_bytes=64 << 20) as srv:
        crop_a = res_a.levels[0].recon[tuple(slice(lo, hi)
                                             for lo, hi in box)]
        np.testing.assert_array_equal(srv.get_roi(box)[0].data, crop_a)
        assert srv.maybe_reload() is False           # unchanged file
        old_crc = srv.snapshot_crc

        tacz.write(path, res_b)                      # atomic republish
        assert srv.maybe_reload() is True
        assert srv.snapshot_crc != old_crc
        assert srv.cache.stats()["entries"] == 0     # cache dropped
        assert not srv._retired                      # idle reader closed
        crop_b = res_b.levels[0].recon[tuple(slice(lo, hi)
                                             for lo, hi in box)]
        np.testing.assert_array_equal(srv.get_roi(box)[0].data, crop_b)
        # repeated republish cycles never accumulate readers/fds
        for seed in (20, 21, 22):
            ds_c = amr.synthetic_amr((32, 32, 32), densities=[0.5, 0.5],
                                     refine_block=4, seed=seed)
            tacz.write(path, hybrid.compress_amr(ds_c, eb=1e-3))
            assert srv.maybe_reload() is True
            srv.get_roi(box)
        assert not srv._retired and not srv._inflight


def test_auto_reload_serves_new_snapshot_without_restart(tmp_path):
    ds_a = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                             seed=3)
    ds_b = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                             seed=4)
    res_a = hybrid.compress_amr(ds_a, eb=1e-2)
    res_b = hybrid.compress_amr(ds_b, eb=1e-2)
    path = os.path.join(str(tmp_path), "auto.tacz")
    tacz.write(path, res_a)
    box = ((0, 16), (0, 16), (0, 16))
    with RegionServer(path, auto_reload=True) as srv:
        np.testing.assert_array_equal(
            srv.get_roi(box)[0].data, res_a.levels[0].recon)
        tacz.write(path, res_b)
        np.testing.assert_array_equal(          # picked up by the next call
            srv.get_roi(box)[0].data, res_b.levels[0].recon)


# -------------------- cache carry-over across hot swap ----------------------


def test_cache_swap_generation_unit():
    kb = np.zeros(256, dtype=np.float32)
    cache = SubBlockCache(budget_bytes=1 << 20)
    for li in (0, 1):
        for sbi in range(3):
            cache.put((111, li, sbi), kb)
    # keep level 0, drop level 1 and any stale generation
    cache.put((99, 0, 7), kb)                     # raced old-gen insert
    kept = cache.swap_generation(111, 222, {0})
    assert kept == 3
    assert len(cache) == 3 and cache.nbytes == 3 * kb.nbytes
    for sbi in range(3):
        assert (222, 0, sbi) in cache
        assert (222, 1, sbi) not in cache
    assert (99, 0, 7) not in cache
    # empty keep set == clear
    assert cache.swap_generation(222, 333, set()) == 0
    assert len(cache) == 0 and cache.nbytes == 0


def test_hot_swap_preserves_cache_for_unchanged_levels(tmp_path):
    """A republish that changed only some levels must keep the other
    levels' decoded bricks warm (matched via per-level index CRCs)."""
    rng = np.random.default_rng(0)
    lvl0_a = rng.normal(size=(32, 32, 32)).astype(np.float32)
    lvl1_a = rng.normal(size=(16, 16, 16)).astype(np.float32)
    lvl1_b = rng.normal(size=(16, 16, 16)).astype(np.float32)
    path = os.path.join(str(tmp_path), "carry.tacz")

    def publish(lvl1):
        with tacz.TACZWriter(path, eb=1e-2) as w:
            w.add_level(lvl0_a, np.ones_like(lvl0_a, bool), ratio=1)
            w.add_level(lvl1, np.ones_like(lvl1, bool), ratio=2)

    publish(lvl1_a)
    box = ((0, 32), (0, 32), (0, 32))
    with RegionServer(path, cache_bytes=64 << 20) as srv:
        srv.get_roi(box)                          # warm both levels
        warm = srv.cache.stats()
        lvl0_keys = [k for k in srv.cache._od if k[1] == 0]
        assert lvl0_keys

        publish(lvl1_b)                           # level 0 bytes unchanged
        assert srv.maybe_reload() is True
        s = srv.cache.stats()
        assert s["entries"] == len(lvl0_keys)     # level 0 carried over
        for key in srv.cache._od:
            assert key[0] == srv.snapshot_crc and key[1] == 0

        with tacz.TACZReader(path) as rd:         # still bit-identical
            ref = rd.read_roi(box)
        got = srv.get_roi(box)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(g.data, r.data)
        after = srv.cache.stats()
        # level 0 served warm (one hit per carried key), only the changed
        # level re-decoded
        assert after["hits"] - warm["hits"] == len(lvl0_keys)
        assert after["misses"] > warm["misses"]

        # a republish where everything changed drops the whole cache
        rng2 = np.random.default_rng(9)
        with tacz.TACZWriter(path, eb=1e-2) as w:
            l0 = rng2.normal(size=(32, 32, 32)).astype(np.float32)
            l1 = rng2.normal(size=(16, 16, 16)).astype(np.float32)
            w.add_level(l0, np.ones_like(l0, bool), ratio=1)
            w.add_level(l1, np.ones_like(l1, bool), ratio=2)
        assert srv.maybe_reload() is True
        assert srv.cache.stats()["entries"] == 0


def test_level_signature_ignores_byte_placement(tmp_path):
    """Same content behind different file offsets (an earlier level grew)
    must produce an equal signature; changed content must not."""
    rng = np.random.default_rng(1)
    small = rng.normal(size=(8, 8, 8)).astype(np.float32)
    big = rng.normal(size=(16, 16, 16)).astype(np.float32)
    shared = rng.normal(size=(16, 16, 16)).astype(np.float32)
    pa = os.path.join(str(tmp_path), "a.tacz")
    pb = os.path.join(str(tmp_path), "b.tacz")
    for p, first in ((pa, small), (pb, big)):
        with tacz.TACZWriter(p, eb=1e-2) as w:
            w.add_level(first, np.ones_like(first, bool), ratio=1)
            w.add_level(shared, np.ones_like(shared, bool), ratio=2)
    with tacz.TACZReader(pa) as ra, tacz.TACZReader(pb) as rb:
        assert ra.level_signature(1) == rb.level_signature(1)
        assert ra.level_signature(0) != rb.level_signature(0)
        # offsets really did differ — the signature ignored them
        assert (ra.levels[1].subblocks[0].payload_off
                != rb.levels[1].subblocks[0].payload_off)


# --------------------------- hypothesis sweeps ------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("serving", max_examples=10, deadline=None)
    settings.load_profile("serving")
except ImportError:        # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(lo=st.tuples(st.integers(0, 60), st.integers(0, 60),
                        st.integers(0, 60)),
           ext=st.tuples(st.integers(1, 64), st.integers(1, 64),
                         st.integers(1, 64)))
    def test_property_random_boxes_cold_and_warm(snapshot, lo, ext):
        path, _ = snapshot
        box = tuple((int(l), int(l + e)) for l, e in zip(lo, ext))
        with tacz.TACZReader(path) as rd, \
                RegionServer(path, cache_bytes=32 << 20) as srv:
            ref = rd.read_roi(box)
            _assert_same_roi(srv.get_roi(box), ref)   # cold
            _assert_same_roi(srv.get_roi(box), ref)   # warm
