"""Huffman codec degenerate cases (ISSUE 2 satellite): empty payloads,
single-symbol codebooks, truncated streams, and codebook serialization —
the edges the TACZ container hits constantly (all-zero bricks quantize to
one-symbol alphabets; empty levels produce empty streams)."""
import numpy as np
import pytest

from repro.core import huffman


def test_empty_stream_roundtrip():
    cb = huffman.build_codebook(np.zeros(0, dtype=np.int64))
    assert len(cb.symbols) == 0
    packed, nbits = huffman.encode(cb, np.zeros(0, dtype=np.int64))
    assert nbits == 0
    out = huffman.decode(cb, packed, nbits, 0)
    assert out.size == 0


def test_empty_codebook_cannot_decode_symbols():
    cb = huffman.build_codebook(np.zeros(0, dtype=np.int64))
    with pytest.raises(ValueError, match="empty codebook"):
        huffman.decode(cb, np.zeros(0, dtype=np.uint8), 0, 3)


def test_single_symbol_roundtrip():
    data = np.full(11, -7, dtype=np.int64)
    cb = huffman.build_codebook(data)
    assert len(cb.symbols) == 1
    packed, nbits = huffman.encode(cb, data)
    assert nbits == 11  # 1 bit per symbol on the wire
    assert nbits == int(huffman.code_lengths_for(cb, data).sum())
    out = huffman.decode(cb, packed, nbits, 11)
    np.testing.assert_array_equal(out, data)


def test_single_symbol_truncation_detected():
    data = np.full(16, 5, dtype=np.int64)
    cb = huffman.build_codebook(data)
    packed, nbits = huffman.encode(cb, data)
    with pytest.raises(ValueError, match="truncated"):
        huffman.decode(cb, packed, nbits - 9, 16)


def test_multi_symbol_truncation_detected():
    rng = np.random.default_rng(0)
    data = rng.integers(-5, 6, size=200)
    cb = huffman.build_codebook(data)
    packed, nbits = huffman.encode(cb, data)
    with pytest.raises(ValueError, match="truncated|corrupt"):
        huffman.decode(cb, packed[: len(packed) // 2], nbits, 200)
    with pytest.raises(ValueError, match="truncated|corrupt"):
        huffman.decode(cb, np.zeros(0, np.uint8), 0, 200)


@pytest.mark.parametrize("n_unique", [1, 2, 17, 300])
def test_encoded_size_bits_matches_encode(n_unique):
    """Regression for the vectorized ``encoded_size_bits``: both call
    forms must price exactly what ``encode`` emits, for every alphabet
    size down to the single-symbol edge."""
    rng = np.random.default_rng(n_unique)
    symbols = rng.choice(5000, size=n_unique, replace=False) - 2500
    data = rng.choice(symbols, size=400)
    cb = huffman.build_codebook(data)
    _, nbits = huffman.encode(cb, data)
    assert huffman.encoded_size_bits(cb, data=data) == nbits
    s, f = np.unique(data, return_counts=True)
    assert huffman.encoded_size_bits(cb, symbols=s, freqs=f) == nbits


def test_encoded_size_bits_empty():
    cb = huffman.build_codebook(np.zeros(0, dtype=np.int64))
    assert huffman.encoded_size_bits(cb,
                                     data=np.zeros(0, np.int64)) == 0
    assert huffman.encoded_size_bits(cb, symbols=np.zeros(0, np.int64),
                                     freqs=np.zeros(0, np.int64)) == 0


@pytest.mark.parametrize("n_unique", [0, 1, 2, 17, 300])
def test_codebook_serialization_roundtrip(n_unique):
    rng = np.random.default_rng(n_unique)
    if n_unique:
        symbols = rng.choice(10_000, size=n_unique, replace=False) - 5000
        freqs = rng.integers(1, 1000, size=n_unique)
        cb = huffman.build_codebook(symbols=symbols, freqs=freqs)
    else:
        cb = huffman.build_codebook(np.zeros(0, dtype=np.int64))
    cb2 = huffman.deserialize_codebook(huffman.serialize_codebook(cb))
    np.testing.assert_array_equal(cb.symbols, cb2.symbols)
    np.testing.assert_array_equal(cb.lengths, cb2.lengths)
    np.testing.assert_array_equal(cb.codes, cb2.codes)
    np.testing.assert_array_equal(cb.first_code, cb2.first_code)
    np.testing.assert_array_equal(cb.first_index, cb2.first_index)
    np.testing.assert_array_equal(cb.count, cb2.count)


def test_codebook_serialization_wide_symbols_use_i64():
    """Symbols beyond int32 force the 8-byte wire width; narrow alphabets
    stay at the 4-byte width that matches codebook_size_bits accounting."""
    wide = huffman.build_codebook(symbols=np.array([0, 2 ** 40]),
                                  freqs=np.array([3, 5]))
    narrow = huffman.build_codebook(symbols=np.array([-5, 7]),
                                    freqs=np.array([3, 5]))
    wbuf, nbuf = (huffman.serialize_codebook(c) for c in (wide, narrow))
    assert len(wbuf) == 5 + 2 * 9
    assert len(nbuf) == 5 + 2 * 5
    for cb, buf in ((wide, wbuf), (narrow, nbuf)):
        cb2 = huffman.deserialize_codebook(buf)
        np.testing.assert_array_equal(cb.symbols, cb2.symbols)
        np.testing.assert_array_equal(cb.codes, cb2.codes)


def test_serialized_codebook_decodes_stream():
    rng = np.random.default_rng(3)
    data = rng.integers(-100, 100, size=500)
    cb = huffman.build_codebook(data)
    packed, nbits = huffman.encode(cb, data)
    cb2 = huffman.deserialize_codebook(huffman.serialize_codebook(cb))
    np.testing.assert_array_equal(huffman.decode(cb2, packed, nbits, 500),
                                  data)


def test_truncated_codebook_buffer_detected():
    cb = huffman.build_codebook(np.arange(10))
    buf = huffman.serialize_codebook(cb)
    for cut in (2, len(buf) - 1):
        with pytest.raises(ValueError, match="truncated"):
            huffman.deserialize_codebook(buf[:cut])
