"""Fleet observability plane (ISSUE 8): FleetCollector ring-buffer
series, SLO rule engine state transitions, /v1/health, the JSON access
log, and the Zipf load generator — unit-level with injected fetch/clock,
plus the live 2-shard acceptance scenario:

  * a ``FleetCollector`` scraping a real 2-shard fleet under loadgen
    traffic produces fleet-aggregated counter totals equal to the sum of
    the per-endpoint ``snapshot()`` values;
  * an SLO latency rule demonstrably walks pending → firing → resolved,
    with the latency injected through ``RegionServer.fault_hook``.
"""
import json
import logging
import os
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.io import TACZReader
from repro.obs import expo
from repro.obs.collect import FleetCollector
from repro.obs.metrics import REGISTRY
from repro.obs.registry import MetricsRegistry
from repro.obs.slo import RULE_TYPES, SLOEngine, SLORule
from repro.serving import (LoadGenerator, RegionClient, ShardedRegionRouter,
                           ShardMap, ZipfWorkload, client_fetch, serve)

BOXES = [((0, 12), (0, 12), (0, 12)), ((8, 24), (4, 20), (10, 26)),
         ((20, 32), (20, 32), (20, 32))]


@pytest.fixture(scope="module")
def snapshot(make_amr_snapshot):
    snap = make_amr_snapshot(densities=[0.35, 0.65], seed=5, name="fleet")
    return snap.path, snap


@pytest.fixture()
def metrics_enabled():
    was = obs.is_enabled()
    obs.set_enabled(True)
    yield
    obs.set_enabled(was)


# --------------------------- collector (fake fetch) ------------------------


class FakeFleet:
    """Injectable fetch/clock: each endpoint serves the render of a
    fresh ``MetricsRegistry`` built by a mutable builder function."""

    def __init__(self, names=("a", "b")):
        self.builders = {n: (lambda reg: None) for n in names}
        self.health = {n: {"status": "ok"} for n in names}
        self.raising = set()
        self.now = 0.0

    def clock(self):
        return self.now

    def fetch(self, url, timeout):
        name = url.rsplit("/", 1)[-1]
        if name in self.raising:
            raise ConnectionError("injected outage")
        reg = MetricsRegistry()
        self.builders[name](reg)
        return reg.render(), self.health.get(name)

    def collector(self, **kw) -> FleetCollector:
        return FleetCollector(
            {n: f"fake://{n}" for n in self.builders},
            fetch=self.fetch, clock=self.clock, **kw)


def test_counter_delta_rate_and_reset():
    fleet = FakeFleet(names=("a", "b"))
    col = fleet.collector()
    vals = {"a": 10.0, "b": 100.0}
    for n in vals:
        fleet.builders[n] = (
            lambda reg, n=n: reg.counter("x_total", "X").inc(vals[n]))
    col.poll()
    assert col.counter_delta("x_total") is None     # one scrape: no delta
    fleet.now = 10.0
    vals["a"], vals["b"] = 25.0, 140.0
    col.poll()
    assert col.counter_delta("x_total") == pytest.approx(15.0 + 40.0)
    assert col.counter_delta("x_total", endpoint="a") == pytest.approx(15)
    assert col.counter_rate("x_total") == pytest.approx(55.0 / 10.0)
    # counter reset (endpoint restarted): post-reset value IS the delta
    fleet.now = 20.0
    vals["a"], vals["b"] = 3.0, 150.0
    col.poll()
    assert col.counter_delta("x_total", window=11.0) \
        == pytest.approx(3.0 + 10.0)
    # a metric nobody serves
    assert col.counter_delta("nope_total") is None


def test_windowed_histogram_quantile_recovers():
    """The property the SLO engine rides: a slow burst ages out of the
    window, so the windowed p99 recovers while the lifetime one cannot."""
    fleet = FakeFleet(names=("a",))
    col = fleet.collector()
    observed = []

    def build(reg):
        h = reg.histogram("lat_seconds", "L", buckets=(0.01, 0.05, 0.1))
        for v in observed:
            h.observe(v)

    fleet.builders["a"] = build
    col.poll()                                   # t=0 baseline: empty
    observed += [0.002] * 10
    fleet.now = 10.0
    col.poll()
    fast = col.quantile("lat_seconds", 0.99, window=30.0)
    assert fast is not None and fast <= 0.01
    observed += [0.09] * 10                      # slow burst
    fleet.now = 20.0
    col.poll()
    slow = col.quantile("lat_seconds", 0.99, window=30.0)
    assert slow is not None and slow > 0.05
    observed += [0.002] * 20                     # fast again
    fleet.now = 40.0
    col.poll()
    fleet.now = 50.0
    col.poll()
    # window [20, 50]: the burst is inside the t=20 baseline, gone from
    # the delta — the windowed p99 recovered
    recovered = col.quantile("lat_seconds", 0.99, window=30.0)
    assert recovered is not None and recovered <= 0.01
    # lifetime histogram never forgets (counts keep the burst)
    lifetime = col.histogram_delta("lat_seconds", window=None)
    assert lifetime.count == 40


def test_gauge_aggregations_and_fleet_families():
    fleet = FakeFleet(names=("a", "b"))
    fleet.builders["a"] = lambda reg: (
        reg.gauge("occ", "O").set(5), reg.counter("c_total", "C").inc(7))
    fleet.builders["b"] = lambda reg: (
        reg.gauge("occ", "O").set(11), reg.counter("c_total", "C").inc(9))
    col = fleet.collector()
    col.poll()
    assert col.gauge("occ", agg="max") == 11
    assert col.gauge("occ", agg="min") == 5
    assert col.gauge("occ", agg="sum") == 16
    with pytest.raises(ValueError):
        col.gauge("occ", agg="avg")
    fam = col.fleet_families()
    assert fam["c_total"]["series"]["_"] == 16.0          # counters sum
    assert fam["occ"]["series"]["_"] == {"max": 11.0, "min": 5.0}


def test_up_down_and_snapshot_dump(tmp_path):
    fleet = FakeFleet(names=("a", "b", "c"))
    fleet.builders["a"] = lambda reg: reg.counter("c_total", "C").inc(1)
    fleet.builders["b"] = lambda reg: reg.counter("c_total", "C").inc(2)
    fleet.builders["c"] = lambda reg: reg.counter("c_total", "C").inc(4)
    fleet.raising.add("b")                       # scrape failure
    fleet.health["c"] = {"status": "down"}       # health-reported down
    col = fleet.collector()
    col.poll()
    assert col.up("a") and not col.up("b") and not col.up("c")
    assert col.up_fraction() == pytest.approx(1 / 3)
    # down endpoints are excluded from fleet aggregation
    assert col.fleet_families()["c_total"]["series"]["_"] == 1.0
    snap = col.snapshot()
    assert snap["endpoints"]["b"]["up"] is False
    assert "injected outage" in snap["endpoints"]["b"]["error"]
    assert snap["endpoints"]["c"]["health"] == {"status": "down"}
    path = col.dump_json(str(tmp_path / "fleet.json"))
    loaded = json.loads(open(path).read())
    assert loaded["fleet"]["c_total"]["series"]["_"] == 1.0
    assert loaded["polls"] == 1


def test_background_polling_thread():
    fleet = FakeFleet(names=("a",))
    fleet.builders["a"] = lambda reg: reg.counter("c_total", "C").inc(1)
    col = fleet.collector()
    col.start(interval=0.01)
    deadline = time.monotonic() + 5.0
    while col.polls < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    col.stop()
    assert col.polls >= 3
    polls = col.polls
    time.sleep(0.05)
    assert col.polls == polls                    # really stopped


# ------------------------------- SLO engine --------------------------------


def test_slo_rule_validation():
    with pytest.raises(ValueError):
        SLORule("r", "nope", "<", 1.0)
    with pytest.raises(ValueError):
        SLORule("r", "gauge", "!=", 1.0)
    with pytest.raises(ValueError):              # duplicate names
        fleet = FakeFleet(names=("a",))
        SLOEngine(fleet.collector(),
                  [SLORule("r", "up", ">=", 1.0),
                   SLORule("r", "up", ">=", 0.5)])
    assert set(RULE_TYPES) >= {"quantile", "quantile_ratio", "rate",
                               "ratio", "error_rate", "gauge", "up"}


def test_slo_gauge_rule_full_state_machine(metrics_enabled):
    """ok → pending → firing → resolved → ok, with `for`-duration and
    no-data hold, against an injected gauge."""
    fleet = FakeFleet(names=("a",))
    value = [5.0]
    present = [True]

    def build(reg):
        if present[0]:
            reg.gauge("queue_depth", "Q").set(value[0])

    fleet.builders["a"] = build
    col = fleet.collector()
    rule = SLORule("queue", "gauge", "<", 10.0, for_seconds=5.0,
                   params={"metric": "queue_depth"})
    eng = SLOEngine(col, [rule], clock=fleet.clock)
    st = eng.states["queue"]

    col.poll()
    eng.evaluate()
    assert st.state == "ok" and st.value == 5.0
    # a blip shorter than for_seconds never fires
    value[0] = 50.0
    fleet.now = 10.0
    col.poll()
    eng.evaluate()
    assert st.state == "pending"
    value[0] = 5.0
    fleet.now = 12.0
    col.poll()
    eng.evaluate()
    assert st.state == "ok" and not st.ever_fired
    # sustained violation escalates after for_seconds
    value[0] = 50.0
    fleet.now = 20.0
    col.poll()
    eng.evaluate()
    assert st.state == "pending"
    fleet.now = 26.0
    col.poll()
    eng.evaluate()
    assert st.state == "firing" and st.ever_fired
    assert eng.firing() == ["queue"] and not eng.passed()
    # firing state is exported back into the scrapable registry
    from repro.obs import metrics as obsm
    assert obsm.SLO_FIRING.labels("queue").value == 1.0
    assert obsm.SLO_STATE.labels("queue").value == 2.0
    assert obsm.SLO_VALUE.labels("queue").value == 50.0
    # no data → no transition (still firing)
    present[0] = False
    fleet.now = 30.0
    col.poll()
    eng.evaluate()
    assert st.state == "firing"
    # healthy again: resolved for exactly one evaluation, then ok
    present[0] = True
    value[0] = 3.0
    fleet.now = 40.0
    col.poll()
    eng.evaluate()
    assert st.state == "resolved"
    assert obsm.SLO_STATE.labels("queue").value == 3.0
    eng.evaluate()
    assert st.state == "ok" and eng.passed()
    report = eng.report()
    assert "queue" in report and "overall: PASS" in report
    verdict = eng.verdict()
    assert verdict["passed"] is True
    assert verdict["rules"]["queue"]["ever_fired"] is True


def test_slo_error_rate_ratio_and_up_rules():
    fleet = FakeFleet(names=("a",))
    http = {"200": 0.0, "500": 0.0}
    cache = {"hits": 0.0, "misses": 0.0}

    def build(reg):
        fam = reg.counter("tacz_http_requests_total", "H",
                          labels=("route", "status"))
        for status, v in http.items():
            fam.labels("/v1/regions", status).inc(v)
        reg.gauge("tacz_cache_hits", "h").set(cache["hits"])
        reg.gauge("tacz_cache_misses", "m").set(cache["misses"])

    fleet.builders["a"] = build
    col = fleet.collector()
    rules = [
        SLORule("errors", "error_rate", "<", 0.001,
                params={"metric": "tacz_http_requests_total"}),
        SLORule("cache_hit_ratio", "ratio", ">", 0.8,
                params={"metric_a": "tacz_cache_hits",
                        "metric_b": "tacz_cache_misses"}),
        SLORule("fleet_up", "up", ">=", 1.0),
        SLORule("throughput", "rate", ">", 1.0,
                params={"metric": "tacz_http_requests_total"}),
    ]
    eng = SLOEngine(col, rules, clock=fleet.clock, export=False)
    col.poll()
    http.update({"200": 900.0, "500": 0.0})
    cache.update({"hits": 90.0, "misses": 5.0})
    fleet.now = 10.0
    col.poll()
    eng.evaluate()
    assert eng.states["errors"].value == 0.0
    assert eng.states["cache_hit_ratio"].value \
        == pytest.approx(90.0 / 95.0)
    assert eng.states["fleet_up"].value == 1.0
    assert eng.states["throughput"].value == pytest.approx(90.0)
    assert eng.passed()
    # a non-2xx burst trips the error-rate rule
    http["500"] += 100.0
    fleet.now = 20.0
    col.poll()
    eng.evaluate()
    err = eng.states["errors"]
    assert err.value == pytest.approx(100.0 / 1000.0)
    assert err.state == "pending" or err.state == "firing"
    assert not eng.passed()


# ----------------------- health / access log satellites --------------------


def test_health_endpoint_ok_and_down(snapshot, tmp_path):
    path, snap = snapshot
    httpd = serve(path, port=0, cache_bytes=4 << 20)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    try:
        client = RegionClient(url)
        h = client.health()
        assert h["status"] == "ok" and h["role"] == "server"
        assert h["snapshot_crc"] == httpd.region_server.snapshot_crc
        assert h["checks"]["snapshot"]["stale"] is False
        assert 0.0 <= h["checks"]["cache"]["headroom"] <= 1.0
        # break the published file: readiness fails but the body says why
        hidden = str(tmp_path / "hidden.tacz")
        os.rename(path, hidden)
        try:
            h = client.health()                  # 503 path returns body
            assert h["status"] == "down"
            assert h["checks"]["snapshot"]["ok"] is False
        finally:
            os.rename(hidden, path)
        assert client.health()["status"] == "ok"
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


def test_json_access_log_option(snapshot, metrics_enabled):
    path, _ = snapshot
    records: list[logging.LogRecord] = []

    class _Capture(logging.Handler):
        def emit(self, record):
            records.append(record)

    handler = _Capture(level=logging.DEBUG)
    logger = logging.getLogger("repro.serving.http")
    old_level = logger.level
    logger.addHandler(handler)
    logger.setLevel(logging.DEBUG)
    httpd = serve(path, port=0, cache_bytes=4 << 20, log_json=True)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    try:
        client = RegionClient(url)
        client.regions(BOXES[:1])
        client.health()
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and len(records) < 2:
            time.sleep(0.01)
        parsed = [json.loads(r.getMessage()) for r in records]
        assert len(parsed) >= 2
        for rec in parsed:
            assert set(rec) == {"method", "path", "status",
                                "duration_ms", "request_id"}
            assert rec["status"] == 200
            assert rec["duration_ms"] >= 0
            assert len(rec["request_id"]) == 16
        assert {r["path"] for r in parsed} >= {"/v1/regions",
                                               "/v1/health"}
    finally:
        logger.removeHandler(handler)
        logger.setLevel(old_level)
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


def test_router_stats_latency_null_safe(snapshot, monkeypatch):
    """Router stats() before any batch: clean nulls, never NaN."""
    path, _ = snapshot
    from repro.obs import metrics as obsm
    fresh = MetricsRegistry().histogram(
        "tacz_router_batch_seconds", "fresh", buckets=(0.1,))
    monkeypatch.setattr(obsm, "ROUTER_BATCH_SECONDS", fresh)
    import repro.serving.sharded as sharded
    monkeypatch.setattr(sharded.obsm, "ROUTER_BATCH_SECONDS", fresh)
    m = ShardMap(["s0"], seed=1)
    with ShardedRegionRouter(path, m, {}) as router:
        lat = router.stats()["latency"]
        assert lat == {"count": 0, "p50_ms": None, "p90_ms": None,
                       "p99_ms": None, "mean_ms": None}
        router.get_regions(BOXES[:1], levels=[0])   # local fallback
        lat = router.stats()["latency"]
        assert lat["count"] == 1 and lat["p50_ms"] >= 0


def test_server_stats_latency_null_safe(snapshot, monkeypatch):
    """A just-started shard scraped before first traffic serves nulls."""
    path, _ = snapshot
    from repro.obs import metrics as obsm
    import repro.serving.regions as regions
    fresh = MetricsRegistry().histogram(
        "tacz_server_request_seconds", "fresh", buckets=(0.1,))
    monkeypatch.setattr(obsm, "SERVER_REQUEST_SECONDS", fresh)
    monkeypatch.setattr(regions.obsm, "SERVER_REQUEST_SECONDS", fresh)
    from repro.serving import RegionServer
    with RegionServer(path, cache_bytes=4 << 20) as rs:
        lat = rs.stats()["latency"]
        assert lat == {"count": 0, "p50_ms": None, "p90_ms": None,
                       "p99_ms": None, "mean_ms": None}
        json.dumps(rs.stats())                   # JSON-clean (no NaN)


# ------------------------------- loadgen -----------------------------------


def test_zipf_workload_shape_and_determinism():
    wl1 = ZipfWorkload((32, 32, 32), population=30, seed=7)
    wl2 = ZipfWorkload((32, 32, 32), population=30, seed=7)
    assert [q.box for q in wl1.queries] == [q.box for q in wl2.queries]
    assert wl1.sequence(50) == wl2.sequence(50)
    sizes = set()
    for q in wl1.queries:
        for (lo, hi), dim in zip(q.box, (32, 32, 32)):
            assert 0 <= lo < hi <= dim
            sizes.add(hi - lo)
    assert {4, 8, 16} <= sizes                   # the three size classes
    # popularity is Zipf-skewed: rank 0 dominates a long sequence
    seq = wl1.sequence(500)
    counts = {}
    for q in seq:
        counts[q.rank] = counts.get(q.rank, 0) + 1
    assert counts.get(0, 0) > counts.get(9, 0)


def test_loadgen_open_loop_against_local_server(snapshot):
    """Loadgen against an in-process fetch: error isolation, exact
    percentiles, and saturation detection."""
    path, _ = snapshot
    calls = []

    def fetch(query):
        calls.append(query)
        if len(calls) == 5:
            raise RuntimeError("injected failure")
        time.sleep(0.001)
        return []

    wl = ZipfWorkload((32, 32, 32), population=8, seed=3)
    gen = LoadGenerator(fetch, wl, rate=500.0, concurrency=4)
    report = gen.run(40)
    assert report.requests == 40 and len(calls) == 40
    assert report.errors == 1
    assert "injected failure" in report.error_messages[0]
    assert report.p50_s <= report.p99_s <= report.max_s
    assert report.verified == 0                  # no reader given
    d = report.to_dict()
    assert d["errors"] == 1 and d["p99_ms"] >= d["p50_ms"]
    # a rate far above capacity reports saturation honestly
    def slow_fetch(query):
        time.sleep(0.01)
        return []
    slow = LoadGenerator(slow_fetch, wl, rate=10_000.0, concurrency=2)
    rep = slow.run(30)
    assert rep.achieved_rate < rep.offered_rate
    assert rep.saturated


# --------------------- live 2-shard fleet acceptance -----------------------


@pytest.fixture()
def fleet(snapshot):
    """2 shard endpoints + a mounted router endpoint, one process."""
    path, snap = snapshot
    m = ShardMap(["s0", "s1"], seed=7)
    servers, urls = {}, {}
    for sid in m.shards:
        httpd = serve(path, port=0, cache_bytes=8 << 20,
                      shard_map=m, shard_id=sid)
        threading.Thread(target=httpd.serve_forever, daemon=True).start()
        servers[sid] = httpd
        urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"
    router = ShardedRegionRouter(path, m,
                                 {k: [v] for k, v in urls.items()})
    rhttpd = serve(router, port=0)
    threading.Thread(target=rhttpd.serve_forever, daemon=True).start()
    urls["router"] = f"http://127.0.0.1:{rhttpd.server_address[1]}"
    yield path, snap, urls, servers, router
    rhttpd.shutdown()
    rhttpd.server_close()
    router.close()
    for httpd in servers.values():
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


def test_fleet_collector_acceptance_under_load(fleet, metrics_enabled):
    """The ISSUE 8 acceptance scenario: collector over a live 2-shard
    fleet under Zipf loadgen traffic; fleet-aggregated counter totals
    equal the sum of per-endpoint snapshot()s; bit-identity sampling
    against the local reader passes."""
    path, snap, urls, servers, router = fleet
    col = FleetCollector(urls, window=16)
    col.poll()
    assert col.up_fraction() == 1.0

    wl = ZipfWorkload((32, 32, 32), levels=(0, 1), population=24, seed=11)
    with TACZReader(path) as rd:
        gen = LoadGenerator(
            client_fetch(RegionClient(urls["router"])), wl,
            rate=100.0, concurrency=4,
            verify_reader=rd, verify_fraction=0.5, seed=1)
        report = gen.run(30)
    assert report.errors == 0, report.error_messages
    assert report.verified > 0 and report.mismatches == 0
    assert report.p99_s is not None and report.achieved_rate > 0

    col.poll()
    # traffic moved the fleet counters between the two polls
    assert col.counter_delta("tacz_router_batches_total",
                             endpoint="router") >= 30
    assert col.quantile("tacz_router_batch_seconds", 0.5) is not None

    # acceptance: fleet totals == sum of per-endpoint snapshot()s.  All
    # endpoints share one process registry, so each per-endpoint scrape
    # equals REGISTRY.snapshot() and the fleet sum is N× that value.
    fam = col.fleet_families()
    reg_snap = REGISTRY.snapshot()
    for metric in ("tacz_server_regions_total",
                   "tacz_router_batches_total",
                   "tacz_router_shard_requests_total"):
        per_endpoint = []
        for name in urls:
            parsed = expo.to_snapshot(col.latest(name).families)
            per_endpoint.append(parsed[metric]["series"]["_"])
        assert fam[metric]["series"]["_"] == pytest.approx(
            sum(per_endpoint))
        assert per_endpoint == [pytest.approx(
            reg_snap[metric]["series"]["_"])] * len(urls)

    # histogram buckets fleet-sum too
    hist = fam["tacz_server_request_seconds"]["series"]["_"]
    want = reg_snap["tacz_server_request_seconds"]["series"]["_"]
    assert hist["count"] == want["count"] * len(urls)
    assert hist["buckets"] == [c * len(urls) for c in want["buckets"]]

    # the mounted router serves the same wire surface as its shards
    rc = RegionClient(urls["router"])
    meta = rc.meta()
    assert "cache" not in meta and meta["shard"]["n_shards"] == 2
    h = rc.health()
    assert h["status"] == "ok" and h["role"] == "router"
    assert all(s["reachable"]
               for s in h["checks"]["shards"].values())
    # a shard going down degrades (local fallback still covers it)
    servers["s0"].shutdown()
    servers["s0"].server_close()
    h = rc.health()
    assert h["status"] == "degraded"
    assert h["checks"]["shards"]["s0"]["reachable"] is False
    col.poll()
    assert col.up("router") and col.up("s1") and not col.up("s0")


def test_slo_latency_rule_fires_and_resolves_on_live_endpoint(
        snapshot, metrics_enabled):
    """At least one SLO rule demonstrably transitions pending → firing →
    resolved, latency injected via the slow-decode fault hook."""
    path, _ = snapshot
    httpd = serve(path, port=0, cache_bytes=8 << 20)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    fake = [0.0]
    try:
        client = RegionClient(url)
        col = FleetCollector({"s0": url}, window=32,
                             clock=lambda: fake[0])
        rule = SLORule(
            "latency_p99", "quantile", "<", 0.05, for_seconds=5.0,
            params={"metric": "tacz_server_request_seconds",
                    "q": 0.99, "window": 25.0})
        eng = SLOEngine(col, [rule], clock=lambda: fake[0])
        st = eng.states["latency_p99"]

        client.regions(BOXES[:1])                # warm the cache
        col.poll()                               # t=0 baseline
        for _ in range(5):
            client.regions(BOXES[:1])            # fast traffic
        fake[0] = 10.0
        col.poll()
        eng.evaluate()
        assert st.state == "ok" and st.value < 0.05
        # inject latency through the fault hook: p99 blows past 50 ms
        httpd.region_server.fault_hook = lambda: time.sleep(0.08)
        for _ in range(6):
            client.regions(BOXES[:1])
        fake[0] = 20.0
        col.poll()
        eng.evaluate()
        assert st.state == "pending" and st.value > 0.05
        fake[0] = 26.0                           # past for_seconds
        eng.evaluate()
        assert st.state == "firing"
        from repro.obs import metrics as obsm
        assert obsm.SLO_FIRING.labels("latency_p99").value == 1.0
        # clear the fault; recent traffic is fast again, and the
        # windowed quantile lets the rule walk back down
        httpd.region_server.fault_hook = None
        for _ in range(12):
            client.regions(BOXES[:1])
        fake[0] = 40.0
        col.poll()
        fake[0] = 45.0
        col.poll()                  # window [20, 45]: burst in baseline
        eng.evaluate()
        assert st.state == "resolved", (st.state, st.value)
        eng.evaluate()
        assert st.state == "ok"
        assert st.ever_fired
        assert obsm.SLO_FIRING.labels("latency_p99").value == 0.0
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()
