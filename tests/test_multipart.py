"""Multi-part parallel TACZ snapshots (ISSUE 5): writer, reader, serving
conformance, crash consistency.

The contract:

  * a multi-part snapshot reads **bit-identically** to the equivalent
    single-file snapshot — ``read``, ``read_roi``, cold/warm
    ``RegionServer``, and the sharded router — across part counts 1–4
    and across v1/v2 payload codecs (property-tested);
  * the write-side partition is the serving-side ``ShardMap``'s
    rendezvous hashing: a shard aligned with its part never opens other
    parts' files;
  * the manifest is the atomic commit point: a killed/failed part writer
    never publishes one, stale ``part-*.tmp`` litter is detected, a
    previously published snapshot stays valid, and a re-run converges.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro import io as tacz
from repro.io import manifest as mfst
from repro.io.parallel import (MultiPartReader, ParallelTACZWriter,
                               write_multipart)
from repro.io.reader import probe_index_crc
from repro.serving import (RegionServer, ShardMap, ShardedRegionRouter,
                           serve)

BOXES = [((0, 8), (0, 8), (0, 8)),
         ((5, 23), (11, 30), (2, 9)),
         ((0, 32), (0, 32), (0, 32)),
         ((14, 18), (14, 18), (14, 18)),
         ((40, 50), (0, 4), (0, 4))]          # beyond the extent


def _assert_identical_reads(single_path, multi_path, res, boxes=BOXES):
    """read()/read_roi() of the multi-part snapshot == single-file."""
    with tacz.TACZReader(single_path) as srd, \
            MultiPartReader(multi_path) as mrd:
        assert mrd.n_levels == srd.n_levels
        assert mrd.subblock_keys() == srd.subblock_keys()
        for a, b in zip(srd.read(), mrd.read()):
            np.testing.assert_array_equal(a, b)
        for box in boxes:
            for a, b in zip(srd.read_roi(box), mrd.read_roi(box)):
                assert (a.level, a.ratio, a.box) == (b.level, b.ratio, b.box)
                np.testing.assert_array_equal(a.data, b.data)
        for lr, rec in zip(res.levels, mrd.read()):
            np.testing.assert_array_equal(lr.recon, rec)


# ----------------------------- deterministic --------------------------------


@pytest.mark.parametrize("parts", [1, 2, 3, 4])
@pytest.mark.parametrize("codec", ["none", "zlib"])
def test_multipart_matches_single_file(make_amr_snapshot, parts, codec):
    """Payload-slice fan-out (shared codebook): bit-identical reads AND
    matching level signatures — part payload bytes equal the single
    file's, so cache carry-over works across single↔multi republish."""
    single = make_amr_snapshot(codec=codec, name="single")
    multi = make_amr_snapshot(codec=codec, parts=parts, name="multi")
    _assert_identical_reads(single.path, multi.path, single.res)
    with tacz.TACZReader(single.path) as srd, \
            MultiPartReader(multi.path) as mrd:
        for li in range(srd.n_levels):
            assert mrd.level_signature(li) == srd.level_signature(li)
        assert mrd.n_parts == parts
        assert mrd.version == srd.version


@pytest.mark.parametrize("mode", ["thread", "process"])
def test_parallel_writer_compresses_raw_levels(tmp_path, make_amr_snapshot,
                                               mode):
    """Mode-B fan-out: each worker compresses its own brick partition —
    per-part codebooks, but decoded values bit-identical to the
    single-writer path."""
    snap = make_amr_snapshot(densities=[0.35, 0.65], seed=5)
    path = os.path.join(str(tmp_path), "raw.taczd")
    with ParallelTACZWriter(path, parts=3, mode=mode, eb=snap.eb) as w:
        for lvl in snap.ds.levels:
            w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)
    _assert_identical_reads(snap.path, path, snap.res)


def test_gsp_level_owned_by_one_part(tmp_path):
    """A single-payload (gsp) level lands whole in its owner part; the
    other parts carry head+mask stubs, and the merged read matches."""
    from repro.core import amr, hybrid
    ds = amr.synthetic_amr((32, 32, 32), densities=[0.9, 0.1],
                           refine_block=4, seed=7)
    lvl = ds.levels[0]
    lr = hybrid.compress_level(lvl.data, lvl.mask, eb=0.01, unit=4,
                               strategy="gsp")
    path = os.path.join(str(tmp_path), "gsp.taczd")
    with ParallelTACZWriter(path, parts=3) as w:
        w.add_compressed(lr)
    body = mfst.load(path)
    owners = [p["levels"][0] for p in body["parts"]]
    assert sorted(sum(owners, [])) == [0]       # exactly one owner
    with MultiPartReader(path) as rd:
        [rec] = rd.read()
        np.testing.assert_array_equal(lr.recon, rec)
    # streaming a raw gsp level through worker-side compression too
    path2 = os.path.join(str(tmp_path), "gsp2.taczd")
    with ParallelTACZWriter(path2, parts=3, eb=0.01, unit=4,
                            strategy="gsp") as w:
        w.add_level(lvl.data, lvl.mask)
    with MultiPartReader(path2) as rd:
        [rec] = rd.read()
        np.testing.assert_array_equal(lr.recon, rec)


def test_region_server_and_router_serve_multipart(make_amr_snapshot):
    """The serving stack works over a snapshot *directory* unchanged:
    cold==warm==single-server, and a part-aligned shard fleet touches
    only its own parts."""
    single = make_amr_snapshot(densities=[0.35, 0.65], seed=5,
                               name="single")
    multi = make_amr_snapshot(densities=[0.35, 0.65], seed=5, parts=3,
                              name="multi")
    with tacz.TACZReader(single.path) as rd, \
            RegionServer(multi.path, cache_bytes=32 << 20) as srv:
        for box in BOXES:
            ref = rd.read_roi(box)
            for g, r in zip(srv.get_roi(box), ref):        # cold
                np.testing.assert_array_equal(g.data, r.data)
            for g, r in zip(srv.get_roi(box), ref):        # warm
                np.testing.assert_array_equal(g.data, r.data)

    # part-aligned fleet: shard ids from the manifest's partition config
    with MultiPartReader(multi.path) as mrd:
        m = ShardMap.from_dict(mrd.partition)
        assert set(m.shards) == {f"part-{i:04d}" for i in range(3)}
    servers, urls = {}, {}
    try:
        for sid in m.shards:
            httpd = serve(multi.path, port=0, cache_bytes=16 << 20,
                          shard_map=m, shard_id=sid)
            threading.Thread(target=httpd.serve_forever,
                             daemon=True).start()
            servers[sid] = httpd
            urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"
        with RegionServer(single.path) as baseline, \
                ShardedRegionRouter(multi.path, m, urls) as router:
            ref = baseline.get_regions(BOXES)
            got = router.get_regions(BOXES)
            for per_got, per_ref in zip(got, ref):
                for g, r in zip(per_got, per_ref):
                    assert (g.level, g.ratio, g.box) == \
                        (r.level, r.ratio, r.box)
                    np.testing.assert_array_equal(g.data, r.data)
            assert router.counters["local_fallbacks"] == 0
        # the locality guarantee: each shard opened ONLY its own part
        for pi, sid in enumerate(sorted(m.shards)):
            reader = servers[sid].region_server.reader
            assert reader.open_parts in ([], [pi]), \
                f"shard {sid} opened foreign parts: {reader.open_parts}"
    finally:
        for httpd in servers.values():
            httpd.shutdown()
            httpd.server_close()
            httpd.region_server.close()


def test_multipart_hot_swap_through_server(tmp_path, make_amr_snapshot):
    """Republishing a multi-part snapshot (even with a different part
    count) hot-swaps through the footer/manifest CRC like a single file,
    and unreferenced old parts are cleaned up."""
    a = make_amr_snapshot(densities=[0.35, 0.65], seed=5)
    b = make_amr_snapshot(densities=[0.5, 0.5], seed=9)
    path = os.path.join(str(tmp_path), "hot.taczd")
    write_multipart(path, a.res, parts=3)
    box = ((0, 32), (0, 32), (0, 32))
    with RegionServer(path, cache_bytes=32 << 20) as srv:
        np.testing.assert_array_equal(srv.get_roi(box)[0].data,
                                      a.res.levels[0].recon)
        old = srv.snapshot_crc
        assert probe_index_crc(path) == old
        write_multipart(path, b.res, parts=2)          # atomic republish
        assert srv.maybe_reload() is True
        assert srv.snapshot_crc != old
        np.testing.assert_array_equal(srv.get_roi(box)[0].data,
                                      b.res.levels[0].recon)
    assert sorted(n for n in os.listdir(path) if n.endswith(".tacz")) == \
        ["part-0000.tacz", "part-0001.tacz"]


# --------------------------- manifest validation ----------------------------


def test_manifest_crc_and_part_binding(make_amr_snapshot):
    multi = make_amr_snapshot(parts=2, name="m")
    mpath = os.path.join(multi.path, mfst.MANIFEST_NAME)

    # CRC mismatch: hand-edited manifest fails loudly
    with open(mpath) as f:
        body = json.load(f)
    body["n_levels"] = 99
    with open(mpath, "w") as f:
        json.dump(body, f)
    with pytest.raises(ValueError, match="CRC"):
        MultiPartReader(multi.path)
    assert probe_index_crc(multi.path) is None

    # truncate a part: fails at open (torn republish — the part no
    # longer matches the manifest's binding)
    multi2 = make_amr_snapshot(parts=2, name="m2")
    part = os.path.join(multi2.path, "part-0001.tacz")
    with open(part, "rb") as f:
        blob = f.read()
    with open(part, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(ValueError):
        MultiPartReader(multi2.path)

    # a *stale* part (valid TACZ, wrong generation) is caught by the
    # manifest's recorded index_crc
    multi3 = make_amr_snapshot(parts=2, name="m3")
    other = make_amr_snapshot(densities=[0.5, 0.5], seed=9, name="other")
    import shutil
    shutil.copy(other.path, os.path.join(multi3.path, "part-0001.tacz"))
    with pytest.raises(ValueError, match="CRC"):
        MultiPartReader(multi3.path)

    # flipped payload bytes inside a part are localized like the
    # single-file case: open succeeds, verify()/reads fail loudly
    multi4 = make_amr_snapshot(parts=2, name="m4")
    part = os.path.join(multi4.path, "part-0000.tacz")
    with open(part, "rb") as f:
        blob = bytearray(f.read())
    with tacz.TACZReader(part) as prd:
        sb = next(sb for e in prd.levels for sb in e.subblocks)
    blob[sb.payload_off + sb.payload_len - 1] ^= 0xFF
    # keep the footer/index intact: only payload bytes changed, so the
    # index CRC still matches and open succeeds
    with open(part, "wb") as f:
        f.write(bytes(blob))
    with MultiPartReader(multi4.path) as rd:
        with pytest.raises(IOError, match="CRC"):
            rd.verify()

    # missing part file
    multi3 = make_amr_snapshot(parts=2, name="m3")
    os.remove(os.path.join(multi3.path, "part-0000.tacz"))
    with pytest.raises(OSError):
        MultiPartReader(multi3.path)


# --------------------------- crash consistency ------------------------------


def test_killed_part_worker_never_publishes(tmp_path, make_amr_snapshot):
    """Kill one part worker mid-republish: close() must fail, the new
    manifest must not appear, the victim's tmp litter is detected — and
    the previously published snapshot must survive *byte-intact* (the
    two-phase commit: no part is renamed until every worker reported)."""
    snap = make_amr_snapshot(densities=[0.35, 0.65], seed=5)
    prior = make_amr_snapshot(densities=[0.5, 0.5], seed=9)
    path = os.path.join(str(tmp_path), "killed.taczd")
    write_multipart(path, prior.res, parts=3)      # snapshot A, published
    crc_a = probe_index_crc(path)
    w = ParallelTACZWriter(path, parts=3, mode="process", eb=snap.eb)
    try:
        w.add_level(snap.ds.levels[0].data, snap.ds.levels[0].mask, ratio=1)
        victim = w._workers[1]
        victim_tmp = os.path.join(path, "part-0001.tacz.tmp")
        deadline = time.time() + 60
        while not os.path.exists(victim_tmp):   # wait for the worker to
            assert time.time() < deadline       # actually be mid-stream
            time.sleep(0.02)
        victim.terminate()
        victim.join()
        with pytest.raises(RuntimeError, match="manifest not published"):
            for _ in range(50):   # the dead worker surfaces on add or close
                w.add_level(snap.ds.levels[1].data, snap.ds.levels[1].mask,
                            ratio=2)
            w.close()
    finally:
        w.abort()                 # what a with-block would do on the raise
    # the surviving workers' tmps were aborted away; the killed worker had
    # no chance to clean its own — detected as stale litter
    assert mfst.stale_parts(path) == ["part-0001.tacz.tmp"]
    # snapshot A is untouched: same generation, bit-identical reads
    assert probe_index_crc(path) == crc_a
    with MultiPartReader(path) as rd:
        for lr, rec in zip(prior.res.levels, rd.read()):
            np.testing.assert_array_equal(lr.recon, rec)


def test_worker_error_aborts_all_parts(tmp_path):
    """A failing encode in any worker surfaces to the producer; no
    manifest, no part files, no tmp litter (orderly abort)."""
    path = os.path.join(str(tmp_path), "err.taczd")
    w = ParallelTACZWriter(path, parts=2, eb=-1.0)   # invalid bound
    with pytest.raises((RuntimeError, ValueError)):
        for _ in range(50):
            w.add_level(np.ones((8, 8, 8), np.float32))
        w.close()
    w.abort()
    assert not os.path.exists(os.path.join(path, mfst.MANIFEST_NAME))
    assert mfst.stale_parts(path) == []
    assert not any(n.endswith(".tacz") for n in os.listdir(path))


def test_crash_rerun_converges_and_keeps_old_snapshot(tmp_path,
                                                      make_amr_snapshot):
    """Kill-style litter (stale tmps, no new manifest) must leave a
    previously published snapshot serving, be detected, and disappear
    after a successful re-run."""
    a = make_amr_snapshot(densities=[0.35, 0.65], seed=5)
    b = make_amr_snapshot(densities=[0.5, 0.5], seed=9)
    path = os.path.join(str(tmp_path), "conv.taczd")
    write_multipart(path, a.res, parts=2)
    crc_a = probe_index_crc(path)

    # simulate a writer killed before publishing snapshot B
    for i in range(2):
        with open(os.path.join(path, mfst.part_name(i) + ".tmp"),
                  "wb") as f:
            f.write(b"half-written garbage")
    assert mfst.stale_parts(path) == ["part-0000.tacz.tmp",
                                      "part-0001.tacz.tmp"]
    # old snapshot still fully valid
    assert probe_index_crc(path) == crc_a
    with MultiPartReader(path) as rd:
        for lr, rec in zip(a.res.levels, rd.read()):
            np.testing.assert_array_equal(lr.recon, rec)

    # re-run converges: new snapshot publishes, litter is gone
    write_multipart(path, b.res, parts=2)
    assert mfst.stale_parts(path) == []
    with MultiPartReader(path) as rd:
        for lr, rec in zip(b.res.levels, rd.read()):
            np.testing.assert_array_equal(lr.recon, rec)


def test_abort_leaves_no_trace(tmp_path, make_amr_snapshot):
    snap = make_amr_snapshot(densities=[0.35, 0.65], seed=5)
    path = os.path.join(str(tmp_path), "abort.taczd")
    w = ParallelTACZWriter(path, parts=2, eb=snap.eb)
    w.add_level(snap.ds.levels[0].data, snap.ds.levels[0].mask, ratio=1)
    w.abort()
    assert not os.path.exists(os.path.join(path, mfst.MANIFEST_NAME))
    assert mfst.stale_parts(path) == []
    with pytest.raises(ValueError):
        w.add_level(snap.ds.levels[0].data, snap.ds.levels[0].mask)


# --------------------------- hypothesis sweeps ------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("multipart", max_examples=6, deadline=None)
    settings.load_profile("multipart")
except ImportError:        # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 40), fine=st.floats(0.1, 0.9),
           parts=st.integers(1, 4), codec=st.sampled_from(["none", "zlib"]),
           lo=st.tuples(st.integers(0, 28), st.integers(0, 28),
                        st.integers(0, 28)),
           ext=st.tuples(st.integers(1, 32), st.integers(1, 32),
                         st.integers(1, 32)))
    def test_property_multipart_reads_bit_identical(make_amr_snapshot, seed,
                                                    fine, parts, codec,
                                                    lo, ext):
        """Random datasets × part counts 1–4 × v1-style/v2 codecs: read,
        read_roi, and a cold+warm RegionServer agree with the single
        file bit for bit."""
        dens = [fine, 1.0 - fine]
        single = make_amr_snapshot(seed=seed, densities=dens, codec=codec,
                                   name="single")
        multi = make_amr_snapshot(seed=seed, densities=dens, codec=codec,
                                  parts=parts, name="multi")
        box = tuple((int(l), int(l + e)) for l, e in zip(lo, ext))
        _assert_identical_reads(single.path, multi.path, single.res,
                                boxes=[box])
        with tacz.TACZReader(single.path) as rd, \
                RegionServer(multi.path, cache_bytes=16 << 20) as srv:
            ref = rd.read_roi(box)
            for pass_ in range(2):              # cold, then warm
                for g, r in zip(srv.get_roi(box), ref):
                    np.testing.assert_array_equal(g.data, r.data)

    @given(seed=st.integers(0, 10),
           lo=st.tuples(st.integers(0, 28), st.integers(0, 28),
                        st.integers(0, 28)),
           ext=st.tuples(st.integers(1, 32), st.integers(1, 32),
                         st.integers(1, 32)))
    @settings(max_examples=5, deadline=None)
    def test_property_router_over_multipart(make_amr_snapshot,
                                            router_fleet, seed, lo, ext):
        """A 2-shard part-aligned router over a multi-part snapshot is
        bit-identical to a single unsharded server on random boxes."""
        single_srv, router = router_fleet
        box = tuple((int(l), int(l + e)) for l, e in zip(lo, ext))
        ref = single_srv.get_regions([box])
        got = router.get_regions([box])
        for per_got, per_ref in zip(got, ref):
            for g, r in zip(per_got, per_ref):
                assert (g.level, g.ratio, g.box) == (r.level, r.ratio, r.box)
                np.testing.assert_array_equal(g.data, r.data)

    @pytest.fixture(scope="module")
    def router_fleet(make_amr_snapshot):
        single = make_amr_snapshot(densities=[0.35, 0.65], seed=5,
                                   name="single")
        multi = make_amr_snapshot(densities=[0.35, 0.65], seed=5, parts=2,
                                  name="multi")
        with MultiPartReader(multi.path) as mrd:
            m = ShardMap.from_dict(mrd.partition)
        servers, urls = {}, {}
        try:
            for sid in m.shards:
                httpd = serve(multi.path, port=0, cache_bytes=16 << 20,
                              shard_map=m, shard_id=sid)
                threading.Thread(target=httpd.serve_forever,
                                 daemon=True).start()
                servers[sid] = httpd
                urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"
            with RegionServer(single.path) as baseline, \
                    ShardedRegionRouter(multi.path, m, urls) as router:
                yield baseline, router
        finally:
            for httpd in servers.values():
                httpd.shutdown()
                httpd.server_close()
                httpd.region_server.close()
