"""Batched entropy engine conformance (ISSUE 6).

Every :mod:`repro.core.entropy` engine must be indistinguishable from the
serial numpy oracle — same encoded bytes, same decoded arrays, and the
same ``ValueError`` on the same (lowest-index) broken payload.  That is
the contract that lets the writer, reader, and serving layers pick an
engine purely on speed: TACZ files stay byte-identical and served crops
stay bit-identical no matter which engine produced or consumed them.

Deterministic parametrized cases run everywhere; hypothesis sweeps run
when the optional dep is installed (same guard as test_she_batched).
"""
import os
import tempfile

import numpy as np
import pytest

from repro.core import entropy, huffman, she, sz

ENGINES = ["numpy", "batched", "pallas"]


def _batch(seed, n_payloads, max_codes, spread=40):
    """(codebook, payload list) — shared codebook over mixed-size payloads
    (including empty ones when n_payloads allows)."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, max_codes + 1, size=n_payloads)
    pool = rng.integers(-spread, spread + 1, size=int(sizes.sum()) + 1)
    cb = huffman.build_codebook(pool)
    splits = np.cumsum(sizes)[:-1]
    return cb, [p.astype(np.int64) for p in np.split(pool[:-1], splits)]


def _outcome(fn, *args, **kw):
    """Result-or-error fingerprint, comparable across engines."""
    try:
        return ("ok", fn(*args, **kw))
    except ValueError as exc:
        return ("err", str(exc))


def _assert_same_outcome(a, b):
    assert a[0] == b[0], (a, b)
    if a[0] == "err":
        assert a[1] == b[1]
    else:
        for x, y in zip(a[1], b[1]):
            if isinstance(x, tuple):
                assert x == y
            else:
                np.testing.assert_array_equal(x, y)


# ------------------------------ registry -----------------------------------


def test_engine_registry():
    for name in ("numpy", "batched", "pallas"):
        eng = entropy.get_engine(name)
        assert eng.name == name
        assert entropy.get_engine(eng) is eng          # instance passthrough
    assert entropy.get_engine("auto").name in ("batched", "pallas")
    with pytest.raises(ValueError, match="unknown entropy engine"):
        entropy.get_engine("cuda")
    entropy.check_engine_name("auto")                  # no jax import needed
    with pytest.raises(ValueError, match="unknown entropy engine"):
        entropy.check_engine_name("cuda")


# --------------------------- encode/decode parity ---------------------------


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("seed,n_payloads,max_codes", [
    (0, 1, 300),       # single payload (below the batch threshold)
    (1, 3, 100),       # still below _MIN_BATCH — serial fallback path
    (2, 12, 200),      # batched path, mixed sizes incl. empty payloads
    (3, 40, 64),       # many small payloads
])
def test_engine_matches_oracle(engine, seed, n_payloads, max_codes):
    cb, codes_list = _batch(seed, n_payloads, max_codes)
    oracle = entropy.get_engine("numpy")
    eng = entropy.get_engine(engine)
    enc_ref = oracle.encode_payloads(cb, codes_list)
    enc = eng.encode_payloads(cb, codes_list)
    assert enc == enc_ref                              # bytes, not just bits
    payloads = [(blob, nbits, c.size)
                for (blob, nbits), c in zip(enc_ref, codes_list)]
    dec = eng.decode_payloads(cb, payloads)
    for out, ref in zip(dec, codes_list):
        assert out.dtype == np.int64
        np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_empty_batch_and_streams(engine):
    eng = entropy.get_engine(engine)
    cb = huffman.build_codebook(np.arange(5))
    assert eng.encode_payloads(cb, []) == []
    assert eng.decode_payloads(cb, []) == []
    enc = eng.encode_payloads(cb, [np.zeros(0, np.int64)] * 6)
    assert enc == [(b"", 0)] * 6
    dec = eng.decode_payloads(cb, [(b"", 0, 0)] * 6)
    assert all(d.size == 0 for d in dec)


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_single_symbol_codebook(engine):
    data = np.full(9, 3, dtype=np.int64)
    cb = huffman.build_codebook(data)
    eng = entropy.get_engine(engine)
    (blob, nbits), = eng.encode_payloads(cb, [data])
    assert nbits == 9
    out, = eng.decode_payloads(cb, [(blob, nbits, 9)])
    np.testing.assert_array_equal(out, data)


# ------------------------------ error parity --------------------------------


@pytest.mark.parametrize("engine", ENGINES[1:])
def test_truncation_error_parity(engine):
    cb, codes_list = _batch(7, 8, 120)
    oracle = entropy.get_engine("numpy")
    eng = entropy.get_engine(engine)
    enc = oracle.encode_payloads(cb, codes_list)
    payloads = [(blob, nbits, c.size)
                for (blob, nbits), c in zip(enc, codes_list)]
    # break one payload several ways; every engine must raise the oracle's
    # exact message (which names the lowest broken payload's failure mode)
    for victim in (0, 3, len(payloads) - 1):
        for cut in (1, 7, 13):
            broken = list(payloads)
            blob, nbits, n = broken[victim]
            if nbits <= cut:
                continue
            broken[victim] = (blob, nbits - cut, n)
            _assert_same_outcome(
                _outcome(oracle.decode_payloads, cb, broken),
                _outcome(eng.decode_payloads, cb, broken))


@pytest.mark.parametrize("engine", ENGINES[1:])
def test_garbage_fuzz_parity(engine):
    """Random buffers/nbits/n_codes: ok-vs-error (and the error text) must
    match the oracle on every payload batch."""
    rng = np.random.default_rng(11)
    cb = huffman.build_codebook(rng.integers(-30, 31, size=4000))
    oracle = entropy.get_engine("numpy")
    eng = entropy.get_engine(engine)
    for _ in range(20):
        batch = []
        for _ in range(int(rng.integers(4, 10))):
            buf = rng.integers(0, 256, size=int(rng.integers(0, 40)),
                               dtype=np.uint8).tobytes()
            nbits = int(rng.integers(0, 8 * max(len(buf), 1) + 8))
            n = int(rng.integers(0, 60))
            batch.append((buf, nbits, n))
        _assert_same_outcome(_outcome(oracle.decode_payloads, cb, batch),
                             _outcome(eng.decode_payloads, cb, batch))


@pytest.mark.parametrize("engine", ENGINES[1:])
def test_incomplete_codebook_corrupt_parity(engine):
    """Only an *incomplete* code (Kraft sum < 1) has a gap the decoder can
    fall into — the one way to hit 'corrupt bitstream' rather than
    'truncated'.  Engines must agree on which it is, case by case."""
    cb = huffman._canonicalize(np.array([1, 2, 3]),
                               np.array([2, 2, 2]))       # gap at code 0b11
    oracle = entropy.get_engine("numpy")
    eng = entropy.get_engine(engine)
    cases = [
        (bytes([0b11000000]), 8, 4),      # lands in the gap → corrupt
        (bytes([0b11000000]), 2, 1),      # gap but stream ends → truncated
        (bytes([0b00011011]), 8, 4),      # valid prefix, then runs out
    ]
    for case in cases:
        batch = [(bytes([0b00011011]), 8, 4), case] * 3   # mixed positions
        _assert_same_outcome(_outcome(oracle.decode_payloads, cb, batch),
                             _outcome(eng.decode_payloads, cb, batch))


@pytest.mark.parametrize("engine", ENGINES)
def test_empty_codebook_raise_parity(engine):
    cb = huffman.build_codebook(np.zeros(0, dtype=np.int64))
    eng = entropy.get_engine(engine)
    out = eng.decode_payloads(cb, [(b"", 0, 0)] * 5)
    assert all(o.size == 0 for o in out)
    with pytest.raises(ValueError, match="empty codebook"):
        eng.decode_payloads(cb, [(b"", 0, 0), (b"\x00", 3, 2)])


# ------------------------- wrapper compatibility ----------------------------


def test_huffman_wrappers_unchanged():
    rng = np.random.default_rng(5)
    data = rng.integers(-50, 51, size=700)
    cb = huffman.build_codebook(data)
    packed, nbits = huffman.encode(cb, data)
    p2, n2 = entropy.encode_stream(cb, data)
    assert nbits == n2 and np.array_equal(packed, p2)
    np.testing.assert_array_equal(huffman.decode(cb, packed, nbits, 700),
                                  entropy.decode_stream(cb, packed, nbits,
                                                        700))


@pytest.mark.parametrize("engine", ENGINES)
def test_she_wrappers_route_engines(engine):
    cb, codes_list = _batch(9, 10, 150)
    enc = she.encode_brick_payloads(cb, codes_list, engine=engine)
    assert enc == she.encode_brick_payloads(cb, codes_list, engine="numpy")
    payloads = [(blob, nbits, c.size)
                for (blob, nbits), c in zip(enc, codes_list)]
    for out, ref in zip(
            she.decode_brick_payloads(cb, payloads, engine=engine),
            codes_list):
        np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("engine", ENGINES)
def test_sz_entropy_stage_engine_param(engine):
    rng = np.random.default_rng(13)
    codes = rng.integers(-20, 21, size=3000)
    ref_bits, ref_cb_bits, ref_art = sz.entropy_stage(codes, engine="numpy")
    bits, cb_bits, art = sz.entropy_stage(codes, engine=engine)
    assert (bits, cb_bits) == (ref_bits, ref_cb_bits)
    assert art["packed"] == ref_art["packed"]
    assert art["nbits"] == ref_art["nbits"]
    np.testing.assert_array_equal(art["codebook"].symbols,
                                  ref_art["codebook"].symbols)


def test_encoded_size_bits_vectorized_regression():
    """`encoded_size_bits` must price exactly what `encode` emits, in both
    call forms, including repeated symbols (the old per-symbol Python loop
    mispriced nothing but was O(n·unique); the vectorized form must keep
    the exact contract)."""
    rng = np.random.default_rng(17)
    data = rng.integers(-9, 10, size=2500)
    cb = huffman.build_codebook(data)
    _, nbits = huffman.encode(cb, data)
    assert huffman.encoded_size_bits(cb, data=data) == nbits
    symbols, freqs = np.unique(data, return_counts=True)
    assert huffman.encoded_size_bits(cb, symbols=symbols,
                                     freqs=freqs) == nbits
    assert huffman.encoded_size_bits(
        cb, symbols=np.zeros(0, np.int64),
        freqs=np.zeros(0, np.int64)) == 0


# ------------------------- end-to-end bit-identity --------------------------


def test_tacz_files_byte_identical_across_engines(tmp_path):
    from repro.io.writer import TACZWriter
    rng = np.random.default_rng(21)
    levels = [rng.normal(size=(24, 24, 24)).astype(np.float32)
              for _ in range(2)]
    blobs = {}
    for engine in ENGINES:
        p = os.path.join(tmp_path, f"{engine}.tacz")
        with TACZWriter(p, eb=1e-3, entropy_engine=engine,
                        lorenzo_engine="numpy") as w:
            for lv in levels:
                w.add_level(lv)
        with open(p, "rb") as f:
            blobs[engine] = f.read()
    assert blobs["batched"] == blobs["numpy"]
    assert blobs["pallas"] == blobs["numpy"]


def test_reader_and_server_identical_across_engines(tmp_path):
    from repro.io.reader import TACZReader
    from repro.serving.regions import RegionServer
    rng = np.random.default_rng(23)
    level = rng.normal(size=(32, 32, 32)).astype(np.float32)
    from repro.io.writer import TACZWriter
    p = os.path.join(tmp_path, "snap.tacz")
    with TACZWriter(p, eb=1e-3, lorenzo_engine="numpy") as w:
        w.add_level(level)
    ref_rd = TACZReader(p, entropy_engine="numpy")
    ref = ref_rd.read_level(0)
    box = ((3, 29), (5, 27), (0, 32))
    ref_roi = ref_rd.read_roi(box)
    for engine in ENGINES[1:]:
        rd = TACZReader(p, entropy_engine=engine)
        np.testing.assert_array_equal(rd.read_level(0), ref)
        for a, b in zip(rd.read_roi(box), ref_roi):
            np.testing.assert_array_equal(a.data, b.data)
        # batched decode surface == serial per-payload surface
        n = len(rd.levels[0].subblocks)
        dec = rd.decode_subblocks(0, list(range(n)))
        for sbi in range(n):
            c, b = ref_rd.subblock_codes(0, sbi)
            np.testing.assert_array_equal(dec[sbi][0], c)
            if b is None:
                assert dec[sbi][1] is None
            else:
                np.testing.assert_array_equal(dec[sbi][1], b)
        rd.close()
        with RegionServer(p, entropy_engine=engine) as srv, \
                RegionServer(p, entropy_engine="numpy") as srv_ref:
            for la, lb in zip(srv.get_roi(box), srv_ref.get_roi(box)):
                np.testing.assert_array_equal(la.data, lb.data)
    ref_rd.close()


def test_multipart_decode_subblocks_across_parts(tmp_path):
    from repro.io.parallel import MultiPartReader, write_multipart
    rng = np.random.default_rng(29)
    from repro.core.amr import synthetic_amr
    ds = synthetic_amr((32, 32, 32), densities=[0.5, 0.5], refine_block=4,
                       seed=3)
    d = os.path.join(tmp_path, "snap")
    write_multipart(d, ds, parts=3, eb=1e-3, lorenzo_engine="numpy")
    with MultiPartReader(d, entropy_engine="batched") as rd, \
            MultiPartReader(d, entropy_engine="numpy") as ref:
        for li in range(len(rd.levels)):
            n = len(rd.levels[li].subblocks)
            if not n:
                continue
            sbis = list(range(n))[::-1]          # arbitrary order
            dec = rd.decode_subblocks(li, sbis)
            for pos, sbi in enumerate(sbis):
                c, b = ref.subblock_codes(li, sbi)
                np.testing.assert_array_equal(dec[pos][0], c)
                if b is not None:
                    np.testing.assert_array_equal(dec[pos][1], b)
            np.testing.assert_array_equal(rd.read_level(li),
                                          ref.read_level(li))


# --------------------------- hypothesis sweeps ------------------------------
#
# Guarded (not importorskip'd at module level) so the deterministic cases
# above still run in environments without the optional hypothesis dep.

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:        # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 10_000), n_payloads=st.integers(1, 24),
           max_codes=st.integers(0, 120), spread=st.integers(0, 300),
           engine=st.sampled_from(ENGINES[1:]))
    def test_property_engines_match_oracle(seed, n_payloads, max_codes,
                                           spread, engine):
        cb, codes_list = _batch(seed, n_payloads, max_codes, spread)
        oracle = entropy.get_engine("numpy")
        eng = entropy.get_engine(engine)
        enc = oracle.encode_payloads(cb, codes_list)
        assert eng.encode_payloads(cb, codes_list) == enc
        payloads = [(blob, nbits, c.size)
                    for (blob, nbits), c in zip(enc, codes_list)]
        for out, ref in zip(eng.decode_payloads(cb, payloads), codes_list):
            np.testing.assert_array_equal(out, ref)

    @given(seed=st.integers(0, 10_000), victim=st.integers(0, 7),
           cut=st.integers(1, 40), engine=st.sampled_from(ENGINES[1:]))
    def test_property_truncation_parity(seed, victim, cut, engine):
        cb, codes_list = _batch(seed, 8, 80)
        oracle = entropy.get_engine("numpy")
        enc = oracle.encode_payloads(cb, codes_list)
        payloads = [(blob, nbits, c.size)
                    for (blob, nbits), c in zip(enc, codes_list)]
        blob, nbits, n = payloads[victim]
        payloads[victim] = (blob, max(nbits - cut, 0), n)
        _assert_same_outcome(
            _outcome(oracle.decode_payloads, cb, payloads),
            _outcome(entropy.get_engine(engine).decode_payloads,
                     cb, payloads))
