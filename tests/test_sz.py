"""SZ core: error-bound property (the paper's contract), exact replay,
Huffman roundtrip.  Property-based via hypothesis."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compat, huffman, sz  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand_field(shape, seed, scale=10.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _bound(eb, x):
    """eb plus the float32-output machine-precision slack (sz.prequant)."""
    return eb + np.abs(x).max() * 2.0 ** -22


# ----------------------------- error bound --------------------------------

@given(seed=st.integers(0, 10_000),
       eb=st.floats(1e-4, 1.0),
       shape=st.sampled_from([(8, 8, 8), (13, 7, 9), (16, 16, 16), (5, 5, 5)]))
def test_error_bound_lorenzo(seed, eb, shape):
    x = _rand_field(shape, seed)
    r = sz.compress_lorenzo(x, eb)
    assert np.abs(r.recon - x).max() <= _bound(eb, x)


@given(seed=st.integers(0, 10_000),
       eb=st.floats(1e-4, 1.0),
       shape=st.sampled_from([(8, 8, 8), (13, 7, 9), (16, 16, 16)]))
def test_error_bound_interp(seed, eb, shape):
    x = _rand_field(shape, seed)
    r = sz.compress_interp(x, eb)
    assert np.abs(r.recon - x).max() <= _bound(eb, x)


@given(seed=st.integers(0, 10_000),
       eb=st.floats(1e-4, 1.0),
       shape=st.sampled_from([(8, 8, 8), (13, 7, 9), (12, 12, 12)]))
def test_error_bound_lor_reg(seed, eb, shape):
    x = _rand_field(shape, seed)
    r = sz.compress_lor_reg(x, eb, block=4)
    assert np.abs(r.recon - x).max() <= _bound(eb, x)


def test_error_bound_4d_bricks():
    x = _rand_field((3, 8, 8, 8), 0)
    for fn in (sz.compress_lorenzo, sz.compress_interp, sz.compress_lor_reg):
        r = fn(x, 0.01)
        assert np.abs(r.recon - x).max() <= _bound(0.01, x), fn.__name__


# ------------------------------ exact replay --------------------------------

@given(seed=st.integers(0, 10_000),
       shape=st.sampled_from([(7,), (9, 5), (8, 8, 8), (6, 9, 17),
                              (3, 4, 4, 4)]))
def test_lorenzo_replay_exact(seed, shape):
    rng = np.random.default_rng(seed)
    q = rng.integers(-10_000, 10_000, size=shape)
    assert (sz.lorenzo_nd_recon(sz.lorenzo_nd_codes(q)) == q).all()


@given(seed=st.integers(0, 10_000),
       shape=st.sampled_from([(7,), (9, 5), (8, 8, 8), (6, 9, 17),
                              (3, 4, 4, 4), (64, 64, 64)]))
def test_interp_replay_exact(seed, shape):
    rng = np.random.default_rng(seed)
    q = rng.integers(-10_000, 10_000, size=shape)
    assert (sz.interp_nd_recon(sz.interp_nd_codes(q)) == q).all()


# ------------------------------ entropy stage --------------------------------

@given(seed=st.integers(0, 10_000), n=st.integers(1, 2000))
def test_huffman_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    data = rng.zipf(1.6, size=n).astype(np.int64) - 500
    cb = huffman.build_codebook(data)
    packed, nbits = huffman.encode(cb, data)
    out = huffman.decode(cb, packed, nbits, n)
    assert (out == data).all()


def test_huffman_single_symbol():
    data = np.full(100, 7, np.int64)
    cb = huffman.build_codebook(data)
    packed, nbits = huffman.encode(cb, data)
    assert (huffman.decode(cb, packed, nbits, 100) == data).all()
    assert nbits == 100  # 1 bit per symbol floor


def test_payload_bits_smaller_for_smooth_data():
    """Smooth data compresses better than noise at the same bound."""
    t = np.linspace(0, 4 * np.pi, 32 ** 3)
    smooth = np.sin(t).reshape(32, 32, 32).astype(np.float32)
    noise = _rand_field((32, 32, 32), 0, scale=1.0)
    eb = 1e-3
    assert (sz.compress_lorenzo(smooth, eb).total_bits
            < sz.compress_lorenzo(noise, eb).total_bits)


@pytest.mark.skipif(not compat.HAVE_ZSTD, reason="needs zstandard")
def test_zstd_helps_constant_field():
    x = np.ones((32, 32, 32), np.float32)
    r = sz.compress_lorenzo(x, 1e-3, use_zstd=True)
    assert r.compression_ratio() > 100  # zstd crushes the all-zero codes


def test_lor_reg_picks_regression_on_noisy_planes():
    """Regression wins on noisy linear ramps: the 3D Lorenzo delta
    amplifies iid noise ~√8× while the plane fit absorbs the ramp."""
    rng = np.random.default_rng(0)
    i, j, k = np.mgrid[0:12, 0:12, 0:12].astype(np.float32)
    eb = 1e-2
    x = 3.0 * i + 2.0 * j - k + rng.normal(
        scale=3 * eb, size=i.shape).astype(np.float32)
    r = sz.compress_lor_reg(x, eb, block=6)
    assert r.extras["branch"] == "reg"
    assert np.abs(r.recon - x).max() <= _bound(eb, x)

    # and Lorenzo wins on a smooth non-linear field
    t = np.linspace(0, np.pi, 12, dtype=np.float32)
    smooth = np.sin(t)[:, None, None] * np.cos(t)[None, :, None] \
        * np.sin(t)[None, None, :]
    r2 = sz.compress_lor_reg(smooth * 100, 1e-2, block=6)
    assert r2.extras["branch"] == "lorenzo"
