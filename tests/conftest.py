"""Shared test fixtures: the AMR snapshot factory (ISSUE 5).

``make_amr_snapshot`` replaces the compress-and-write boilerplate that
was duplicated across ``test_tacz.py``, ``test_region_serving.py``, and
``test_sharded_serving.py``: one call builds (or reuses) a compressed
AMR dataset and writes it as a single-file ``.tacz`` snapshot or — with
``parts=N`` — a multi-part ``.taczd`` snapshot directory.

The expensive part (synthesize + ``compress_amr``) is cached per
parameter set for the whole session, so modules sharing a dataset pay
for compression once; the snapshot *file* is written fresh per call
(tests mutate/republish files, never the cached result).
"""
import os
from types import SimpleNamespace

import pytest

from repro import io as tacz
from repro.core import amr, hybrid
from repro.io.parallel import write_multipart

#: (dataset args) -> (ds, res, eb); session-wide compression cache.
_COMPRESS_CACHE: dict = {}


def _default_densities(levels: int) -> list[float]:
    """A deterministic density split for an n-level synthetic dataset
    (``synthetic_amr`` normalizes the sum itself)."""
    return [0.35, 0.65, 0.45, 0.55, 0.25, 0.75][:levels] or [1.0]


@pytest.fixture(scope="session")
def make_amr_snapshot(tmp_path_factory):
    """Factory fixture: ``make_amr_snapshot(levels, seed, codec, parts)``.

    :param levels: synthetic level count (ignored when ``preset`` given).
    :param seed: synthetic dataset seed.
    :param codec: TACZ payload codec (``"auto"``/``"zlib"``/``"none"``).
    :param parts: None → single ``.tacz`` file; N ≥ 1 → multi-part
        ``.taczd`` snapshot directory with N parts.
    :param preset: use ``amr.load_preset(preset)`` instead of synthesis.
    :param shape: finest grid shape for synthetic datasets.
    :param densities: per-level densities (default: a fixed split).
    :param eb_rel: error bound as a fraction of the finest level's range.
    :param mode: parallel-writer worker mode for multi-part snapshots.
    :param name: snapshot base name inside a fresh tmp directory.
    :returns: ``SimpleNamespace(path, res, ds, eb)``.
    """
    def factory(levels: int = 2, seed: int = 5, codec: str = "auto",
                parts: int | None = None, *, preset: str | None = None,
                shape=(32, 32, 32), densities=None, eb_rel: float = 1e-3,
                refine_block: int | None = None, mode: str = "thread",
                name: str = "snap"):
        if densities is not None:
            levels = len(densities)
        if refine_block is None:
            # the coarsest ratio (2^(L-1)) must divide the refine block
            refine_block = max(4, 2 ** (levels - 1))
        key = (levels, seed, preset, tuple(shape),
               tuple(densities) if densities else None, eb_rel,
               refine_block)
        if key not in _COMPRESS_CACHE:
            if preset is not None:
                ds = amr.load_preset(preset)
            else:
                ds = amr.synthetic_amr(
                    tuple(shape),
                    densities=densities or _default_densities(levels),
                    refine_block=refine_block, seed=seed)
            eb = eb_rel * float(ds.levels[0].data.max()
                                - ds.levels[0].data.min())
            res = hybrid.compress_amr(ds, eb=eb)
            _COMPRESS_CACHE[key] = (ds, res, eb)
        ds, res, eb = _COMPRESS_CACHE[key]
        d = tmp_path_factory.mktemp("snap")
        if parts is None:
            path = os.path.join(str(d), name + ".tacz")
            tacz.write(path, res, payload_codec=codec)
        else:
            path = os.path.join(str(d), name + ".taczd")
            write_multipart(path, res, parts=parts, mode=mode,
                            payload_codec=codec)
        return SimpleNamespace(path=path, res=res, ds=ds, eb=eb)

    return factory
