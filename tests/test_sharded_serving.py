"""Sharded region serving (ISSUE 4): shard map + scatter-gather router.

The contract:

  * :class:`ShardMap` is a pure function of its serialized config — the
    router ("client side") and the shard servers ("server side") compute
    identical owners from the same JSON, independent of shard-list order;
  * resizing moves the minimum: adding a shard only moves keys *to* it,
    removing one only moves the keys it owned;
  * ``ShardedRegionRouter.get_regions`` is bit-identical to a single
    unsharded ``RegionServer.get_regions`` across shard counts 1–4,
    including with one shard unreachable (replica retry and direct local
    ``TACZReader`` fallback);
  * shard-filtered servers decode/cache only owned sub-blocks;
  * a shard serving a stale snapshot generation is detected via the
    footer ``index_crc`` and routed around, never mixed in.
"""
import contextlib
import os
import socket
import threading

import numpy as np
import pytest

from repro import io as tacz
from repro.core import amr, hybrid
from repro.io.reader import WHOLE_LEVEL
from repro.serving import RegionServer, ShardMap, ShardedRegionRouter, serve

BOXES = [((0, 8), (0, 8), (0, 8)),
         ((5, 23), (11, 30), (2, 9)),
         ((24, 32), (16, 32), (0, 32)),
         ((0, 32), (0, 32), (0, 32)),
         ((14, 18), (14, 18), (14, 18)),
         ((40, 50), (0, 4), (0, 4))]          # beyond the extent


@pytest.fixture(scope="module")
def snapshot(make_amr_snapshot):
    snap = make_amr_snapshot(densities=[0.35, 0.65], seed=5, name="s")
    return snap.path, snap.res


@pytest.fixture(scope="module")
def file_keys(snapshot):
    path, _ = snapshot
    with tacz.TACZReader(path) as rd:
        return rd.subblock_keys()


@contextlib.contextmanager
def shard_fleet(path, shard_map, *, cache_bytes=16 << 20, auto_reload=True):
    """Launch one HTTP endpoint per shard; yields {shard_id: url} plus the
    raw servers (for fault injection)."""
    servers, urls = {}, {}
    try:
        for sid in shard_map.shards:
            httpd = serve(path, port=0, cache_bytes=cache_bytes,
                          auto_reload=auto_reload, shard_map=shard_map,
                          shard_id=sid)
            threading.Thread(target=httpd.serve_forever,
                             daemon=True).start()
            servers[sid] = httpd
            urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"
        yield urls, servers
    finally:
        for httpd in servers.values():
            httpd.shutdown()
            httpd.server_close()
            httpd.region_server.close()


def dead_url() -> str:
    """An endpoint URL that refuses connections immediately."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    return f"http://127.0.0.1:{port}"


def _assert_same_regions(got, ref):
    assert len(got) == len(ref)
    for per_got, per_ref in zip(got, ref):
        assert len(per_got) == len(per_ref)
        for g, r in zip(per_got, per_ref):
            assert (g.level, g.ratio, g.box) == (r.level, r.ratio, r.box)
            np.testing.assert_array_equal(g.data, r.data)


# ------------------------------- shard map ----------------------------------


def test_shard_map_client_server_agreement(file_keys):
    """The router and a shard server built from the same serialized config
    must compute identical owners — the whole scheme rests on this."""
    server_side = ShardMap(["s0", "s1", "s2"], seed=11)
    client_side = ShardMap.from_json(server_side.to_json())
    assert client_side == server_side
    for key in file_keys:
        assert client_side.owner(key) == server_side.owner(key)
    # dict round-trip too (what a deployment config file would hold)
    assert ShardMap.from_dict(server_side.to_dict()) == server_side


def test_shard_map_order_and_process_independence(file_keys):
    a = ShardMap(["x", "y", "z"], seed=3)
    b = ShardMap(["z", "x", "y"], seed=3)
    assert a == b
    assert all(a.owner(k) == b.owner(k) for k in file_keys)
    # seed reshuffles; different seeds give (almost surely) different maps
    c = ShardMap(["x", "y", "z"], seed=4)
    keys = [(li, sbi) for li in range(4) for sbi in range(64)]
    assert any(a.owner(k) != c.owner(k) for k in keys)


def test_shard_map_covers_whole_level_keys():
    m = ShardMap(["a", "b"])
    assert m.owner((2, WHOLE_LEVEL)) in m.shards


def test_shard_map_minimal_movement_on_add():
    m = ShardMap([f"s{i}" for i in range(3)], seed=0)
    keys = [(li, sbi) for li in range(4) for sbi in range(128)]
    grown = m.with_shard("s3")
    moved = [k for k in keys if m.owner(k) != grown.owner(k)]
    # rendezvous: every moved key lands on the NEW shard only
    assert all(grown.owner(k) == "s3" for k in moved)
    # and roughly 1/(N+1) of the keys move (generous bounds, 512 keys)
    assert 0.10 * len(keys) < len(moved) < 0.45 * len(keys)


def test_shard_map_minimal_movement_on_remove():
    m = ShardMap([f"s{i}" for i in range(4)], seed=0)
    keys = [(li, sbi) for li in range(4) for sbi in range(128)]
    shrunk = m.without_shard("s1")
    for k in keys:
        if m.owner(k) != "s1":          # survivors keep every key
            assert shrunk.owner(k) == m.owner(k)
        else:
            assert shrunk.owner(k) in shrunk.shards


def test_shard_map_partition_is_total_and_disjoint(file_keys):
    m = ShardMap(["a", "b", "c"], seed=1)
    part = m.partition(file_keys)
    flat = [k for keys in part.values() for k in keys]
    assert sorted(flat) == sorted(file_keys)
    assert set(part) <= set(m.shards)


def test_shard_map_validation():
    with pytest.raises(ValueError):
        ShardMap([])
    with pytest.raises(ValueError):
        ShardMap(["a", "a"])
    with pytest.raises(ValueError):
        ShardMap(["a", ""])
    with pytest.raises(ValueError):
        ShardMap(["a"]).with_shard("a")
    with pytest.raises(ValueError):
        ShardMap(["a", "b"]).without_shard("nope")
    with pytest.raises(ValueError, match="algorithm"):
        ShardMap.from_dict({"algorithm": "ring-md5", "shards": ["a"]})


# --------------------------- router vs single server ------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_router_bit_identical_to_single_server(snapshot, n_shards):
    path, _ = snapshot
    m = ShardMap([f"s{i}" for i in range(n_shards)], seed=2)
    with RegionServer(path) as single, \
            shard_fleet(path, m) as (urls, _servers), \
            ShardedRegionRouter(path, m, urls) as router:
        ref = single.get_regions(BOXES)
        _assert_same_regions(router.get_regions(BOXES), ref)
        # repeat batch (shard caches warm now) — still identical
        _assert_same_regions(router.get_regions(BOXES), ref)
        assert router.counters["local_fallbacks"] == 0
        # level-filtered and single-region forms route the same way
        np.testing.assert_array_equal(
            router.get_region(1, BOXES[1]).data,
            single.get_region(1, BOXES[1]).data)


@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_router_with_one_shard_unreachable(snapshot, n_shards):
    """Killing one shard must cost throughput only: the router decodes
    that shard's rectangles from the local file, bit-identically."""
    path, _ = snapshot
    m = ShardMap([f"s{i}" for i in range(n_shards)], seed=2)
    with RegionServer(path) as single, shard_fleet(path, m) as (urls, _):
        down = m.shards[0]
        urls = dict(urls, **{down: dead_url()})
        with ShardedRegionRouter(path, m, urls) as router:
            ref = single.get_regions(BOXES)
            _assert_same_regions(router.get_regions(BOXES), ref)
            assert router.counters["local_fallbacks"] > 0
            assert router.counters["endpoint_failures"] > 0


def test_router_replica_retry_avoids_fallback(snapshot):
    """A dead primary with a live replica must be absorbed by the retry,
    never reaching the local-fallback path."""
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=2)
    with RegionServer(path) as single, shard_fleet(path, m) as (urls, _):
        routed = {m.shards[0]: [dead_url(), urls[m.shards[0]]],
                  m.shards[1]: urls[m.shards[1]]}
        with ShardedRegionRouter(path, m, routed) as router:
            _assert_same_regions(router.get_regions(BOXES),
                                 single.get_regions(BOXES))
            assert router.counters["endpoint_failures"] > 0
            assert router.counters["local_fallbacks"] == 0


def test_router_load_balances_across_replicas(snapshot):
    """With load_balance=True, a shard's read traffic must spread across
    its healthy replica endpoints (both see work) — and the reassembled
    bytes must be unchanged."""
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=2)
    with RegionServer(path) as single, shard_fleet(path, m) as (urls, _):
        # a second full replica endpoint for shard s0
        with shard_fleet(path, ShardMap(["s0"], seed=2)) as (r_urls,
                                                            r_servers):
            routed = {"s0": [urls["s0"], r_urls["s0"]], "s1": urls["s1"]}
            with ShardedRegionRouter(path, m, routed,
                                     load_balance=True) as router:
                ref = single.get_regions(BOXES)
                for _ in range(4):             # several batches → rotation
                    _assert_same_regions(router.get_regions(BOXES), ref)
                assert router.counters["local_fallbacks"] == 0
                assert router.counters["endpoint_failures"] == 0
                assert router.stats()["unhealthy_endpoints"] == []
            replica = r_servers["s0"].region_server
            s = replica.cache.stats()
            assert s["hits"] + s["misses"] > 0     # the replica saw reads


def test_router_load_balance_demotes_dead_endpoint(snapshot):
    """A dead replica in the rotation is demoted after its first failure:
    batches keep succeeding off the healthy endpoint, bytes unchanged."""
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=2)
    with RegionServer(path) as single, shard_fleet(path, m) as (urls, _):
        routed = {"s0": [dead_url(), urls["s0"]], "s1": urls["s1"]}
        with ShardedRegionRouter(path, m, routed,
                                 load_balance=True) as router:
            ref = single.get_regions(BOXES)
            for _ in range(3):
                _assert_same_regions(router.get_regions(BOXES), ref)
            assert router.counters["local_fallbacks"] == 0
            assert router.counters["endpoint_failures"] > 0
            assert router.stats()["unhealthy_endpoints"] == \
                [routed["s0"][0]]


def test_router_missing_endpoint_uses_local_fallback(snapshot):
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=2)
    with RegionServer(path) as single, shard_fleet(path, m) as (urls, _):
        partial = {m.shards[0]: urls[m.shards[0]]}   # s1 not deployed yet
        with ShardedRegionRouter(path, m, partial) as router:
            _assert_same_regions(router.get_regions(BOXES),
                                 single.get_regions(BOXES))
            assert router.counters["local_fallbacks"] > 0


def test_router_without_local_fallback_raises(snapshot):
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=2)
    with shard_fleet(path, m) as (urls, _):
        bad = dict(urls, **{m.shards[0]: dead_url()})
        with ShardedRegionRouter(path, m, bad,
                                 local_fallback=False) as router:
            with pytest.raises(RuntimeError, match="unreachable"):
                router.get_regions([((0, 32), (0, 32), (0, 32))])


def test_router_rejects_bad_levels(snapshot):
    path, _ = snapshot
    m = ShardMap(["s0"], seed=0)
    with shard_fleet(path, m) as (urls, _), \
            ShardedRegionRouter(path, m, urls) as router:
        with pytest.raises(ValueError, match="out of range"):
            router.get_regions([BOXES[0]], levels=[99])


# ------------------------------ shard filter --------------------------------


def test_shard_servers_cache_only_owned_keys(snapshot):
    path, _ = snapshot
    m = ShardMap(["s0", "s1", "s2"], seed=9)
    with tacz.TACZReader(path) as rd:
        owned = {sid: {k for k in rd.subblock_keys() if m.owner(k) == sid}
                 for sid in m.shards}
    with shard_fleet(path, m) as (urls, servers), \
            ShardedRegionRouter(path, m, urls) as router:
        router.get_regions(BOXES)
        total = 0
        for sid, httpd in servers.items():
            rs = httpd.region_server
            for key in list(rs.cache._od):
                assert (key[1], key[2]) in owned[sid], \
                    f"shard {sid} cached foreign sub-block {key}"
            total += len(rs.cache._od)
        assert total > 0                      # the fleet did cache work
        # disjointness: every decoded key sits in exactly one shard cache
        all_cached = [(key[1], key[2]) for httpd in servers.values()
                      for key in httpd.region_server.cache._od]
        assert len(all_cached) == len(set(all_cached))


def test_shard_filtered_server_zeros_foreign_cells(snapshot):
    """A lone shard server queried directly serves zeros where it does not
    own the sub-block — the router's overlay relies on exactly that."""
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=9)
    box = ((0, 32), (0, 32), (0, 32))
    with RegionServer(path) as full, \
            RegionServer(path, shard_map=m, shard_id="s0") as s0, \
            RegionServer(path, shard_map=m, shard_id="s1") as s1:
        ref = full.get_roi(box)
        a, b = s0.get_roi(box), s1.get_roi(box)
        for r, ga, gb in zip(ref, a, b):
            # each cell comes from exactly one owner; the other is zero,
            # so overlaying the two shard crops rebuilds the full crop
            overlay = np.where(ga.data != 0, ga.data, gb.data)
            np.testing.assert_array_equal(overlay, r.data)
    with pytest.raises(ValueError, match="go together"):
        RegionServer(path, shard_map=m)


def test_shard_meta_reports_shard_info(snapshot):
    from repro.serving import RegionClient
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=4)
    with shard_fleet(path, m) as (urls, _):
        meta = RegionClient(urls["s0"]).meta()
        assert meta["shard"]["shard_id"] == "s0"
        assert meta["shard"]["n_shards"] == 2
        assert ShardMap.from_dict(meta["shard"]["shard_map"]) == m


# ------------------------------- hot swap -----------------------------------


def test_hot_swap_propagates_through_router(tmp_path):
    ds_a = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                             seed=3)
    ds_b = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                             seed=4)
    res_a = hybrid.compress_amr(ds_a, eb=1e-2)
    res_b = hybrid.compress_amr(ds_b, eb=1e-2)
    path = os.path.join(str(tmp_path), "hot.tacz")
    tacz.write(path, res_a)
    box = ((0, 16), (0, 16), (0, 16))
    m = ShardMap(["s0", "s1"], seed=0)
    with shard_fleet(path, m) as (urls, _), \
            ShardedRegionRouter(path, m, urls) as router:
        np.testing.assert_array_equal(
            router.get_roi(box)[0].data, res_a.levels[0].recon)
        old_crc = router.snapshot_crc
        tacz.write(path, res_b)               # atomic republish
        np.testing.assert_array_equal(        # next batch serves the new one
            router.get_roi(box)[0].data, res_b.levels[0].recon)
        assert router.snapshot_crc != old_crc
        assert router.counters["local_fallbacks"] == 0


def test_stale_shard_generation_is_routed_around(tmp_path):
    """A shard that has not adopted a republish yet (auto_reload off here,
    file-distribution lag in real deployments) answers with the old index
    CRC — the router must treat it as failed, not mix generations."""
    ds_a = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                             seed=3)
    ds_b = amr.synthetic_amr((16, 16, 16), densities=[1.0], refine_block=4,
                             seed=4)
    res_a = hybrid.compress_amr(ds_a, eb=1e-2)
    res_b = hybrid.compress_amr(ds_b, eb=1e-2)
    path = os.path.join(str(tmp_path), "lag.tacz")
    tacz.write(path, res_a)
    box = ((0, 16), (0, 16), (0, 16))
    m = ShardMap(["s0"], seed=0)
    with shard_fleet(path, m, auto_reload=False) as (urls, _), \
            ShardedRegionRouter(path, m, urls) as router:
        router.get_roi(box)                   # both sides on snapshot A
        tacz.write(path, res_b)
        roi = router.get_roi(box)[0]          # router reloads; shard lags
        np.testing.assert_array_equal(roi.data, res_b.levels[0].recon)
        assert router.counters["local_fallbacks"] > 0


# --------------------------- hypothesis sweeps ------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("sharded", max_examples=10, deadline=None)
    settings.load_profile("sharded")
except ImportError:        # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @pytest.fixture(scope="module")
    def fleet3(snapshot):
        path, _ = snapshot
        m = ShardMap(["s0", "s1", "s2"], seed=6)
        with shard_fleet(path, m) as (urls, servers):
            # s2 is permanently down: every example also exercises the
            # local-fallback path alongside the two live shards
            urls = dict(urls, **{"s2": dead_url()})
            with RegionServer(path) as single, \
                    ShardedRegionRouter(path, m, urls) as router:
                yield single, router

    @given(lo=st.tuples(st.integers(0, 28), st.integers(0, 28),
                        st.integers(0, 28)),
           ext=st.tuples(st.integers(1, 32), st.integers(1, 32),
                         st.integers(1, 32)))
    def test_property_random_boxes_sharded(fleet3, lo, ext):
        single, router = fleet3
        box = tuple((int(l), int(l + e)) for l, e in zip(lo, ext))
        _assert_same_regions(router.get_regions([box]),
                             single.get_regions([box]))

    @given(seed=st.integers(0, 2 ** 31), n=st.integers(1, 9))
    def test_property_rendezvous_add_only_moves_to_new(seed, n):
        m = ShardMap([f"s{i}" for i in range(n)], seed=seed)
        grown = m.with_shard("new")
        keys = [(li, sbi) for li in range(3) for sbi in range(32)]
        for k in keys:
            before, after = m.owner(k), grown.owner(k)
            assert after == before or after == "new"
